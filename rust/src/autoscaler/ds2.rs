//! DS2-style reactive autoscaler (Kalavri et al., OSDI '18) — the paper's
//! §2 "three steps is all you need" comparison point, in its **true
//! per-operator formulation**.
//!
//! DS2 computes each operator's *true processing rate* (tuples/s of pure
//! processing, excluding idle/back-pressure time) and jumps every operator
//! directly to the minimal parallelism whose aggregate true rate covers
//! that operator's share of the source rate. On a staged deployment
//! ([`crate::dsp::StageModel::Staged`]) this controller therefore emits a
//! **vector** of per-stage parallelisms ([`ScalePlan::PerStage`]): per-stage
//! busy fractions → per-stage true rates → per-stage targets, with observed
//! output/input ratios propagating the source rate down the chain exactly
//! as DS2's instrumented dataflow graph does. It is purely reactive (no
//! forecasting) and assumes the workload holds still while it converges —
//! the limitations Daedalus targets (§2).
//!
//! On the fused flat pool the retained **job-level** path applies: a
//! worker's busy fraction is estimated as `(cpu − idle) / (cpu_sat − idle)`
//! with `idle`/`cpu_sat` calibrated conservatively from the observed CPU
//! range, and the job jumps to a single parallelism. The staged path reads
//! the engine's exact `stage_busy` instrumentation instead — real DS2
//! instruments operator useful-time precisely, which is why its
//! per-operator targets are tight where coarse CPU calibration must be
//! conservative.

use super::{guard, Autoscaler};
use crate::clock::Timestamp;
use crate::dsp::engine::{ScalePlan, SimView};
use crate::metrics::query::{StageMonitor, StageSnapshot, WorkerMonitor, WorkerSnapshot};

/// DS2 tuning.
#[derive(Debug, Clone)]
pub struct Ds2Config {
    /// Decision interval (seconds) — DS2 evaluates on policy windows.
    pub interval: u64,
    /// Activation threshold: rescale only if the target differs from the
    /// current parallelism by at least this many workers.
    pub min_delta: usize,
    /// Headroom factor on the computed minimum (DS2's ρ ≈ utilization cap).
    pub headroom: f64,
    /// Cooldown after a rescale (convergence wait).
    pub cooldown: u64,
    /// Lower parallelism bound.
    pub min_replicas: usize,
    /// Upper parallelism bound (cluster size).
    pub max_replicas: usize,
}

impl Ds2Config {
    /// DS2 defaults at a given cluster size.
    pub fn defaults(max_replicas: usize) -> Self {
        Self {
            interval: 60,
            min_delta: 1,
            headroom: 1.1,
            cooldown: 180,
            min_replicas: 1,
            max_replicas,
        }
    }
}

/// Reconfiguration granularity of the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Ds2Mode {
    /// True DS2: every operator jumps to its own minimal parallelism
    /// (per-stage vector on a staged deployment).
    #[default]
    PerOperator,
    /// Job-level reconfiguration: the worst operator's requirement is
    /// applied to *every* operator uniformly (Flink reactive-mode
    /// semantics) — the comparison baseline for the granularity dividend.
    JobLevel,
}

/// The 1-minute policy window DS2 evaluates its instrumentation over.
const DS2_WINDOW: u64 = 60;

/// The DS2-like controller.
pub struct Ds2 {
    cfg: Ds2Config,
    mode: Ds2Mode,
    last_decision: Option<Timestamp>,
    last_rescale: Option<Timestamp>,
    /// Running estimate of the idle-CPU floor (min CPU ever seen).
    idle_floor: f64,
    /// Running estimate of the saturation ceiling (max CPU ever seen).
    sat_ceiling: f64,
    /// Incremental per-stage instrumentation view (pre-resolved handles +
    /// rolling windows) and its reusable output buffer.
    stage_monitor: StageMonitor,
    stage_snaps: Vec<StageSnapshot>,
    /// Cached per-worker handle table + reusable snapshot buffer (fused).
    worker_monitor: WorkerMonitor,
    worker_snaps: Vec<WorkerSnapshot>,
}

impl Ds2 {
    /// Per-operator DS2 (the true formulation).
    pub fn new(cfg: Ds2Config) -> Self {
        Self::with_mode(cfg, Ds2Mode::PerOperator)
    }

    /// Job-level variant (uniform vector on staged deployments).
    pub fn job_level(cfg: Ds2Config) -> Self {
        Self::with_mode(cfg, Ds2Mode::JobLevel)
    }

    /// Controller with an explicit reconfiguration granularity.
    pub fn with_mode(cfg: Ds2Config, mode: Ds2Mode) -> Self {
        Self {
            cfg,
            mode,
            last_decision: None,
            last_rescale: None,
            idle_floor: 0.05,
            sat_ceiling: 0.5,
            stage_monitor: StageMonitor::new(DS2_WINDOW),
            stage_snaps: Vec::new(),
            worker_monitor: WorkerMonitor::new(),
            worker_snaps: Vec::new(),
        }
    }

    /// Shared gating: readiness, decision interval, post-rescale cooldown.
    /// Marks the decision slot when it passes.
    fn gate(&mut self, view: &SimView<'_>) -> bool {
        if !view.ready {
            return false;
        }
        if let Some(t) = self.last_decision {
            if view.now < t + self.cfg.interval {
                return false;
            }
        }
        if let Some(t) = self.last_rescale {
            if view.now < t + self.cfg.cooldown {
                return false;
            }
        }
        // Degraded telemetry: hold without consuming the decision slot,
        // so the controller re-evaluates as soon as its senses recover.
        if view.tsdb.degraded() {
            return false;
        }
        self.last_decision = Some(view.now);
        true
    }

    /// Exact next-possible-action tick: before the decision interval (and
    /// the post-rescale cooldown) elapse, `gate` returns `false` without
    /// touching `last_decision`, so every intermediate `decide` call is a
    /// pure no-op and may be skipped by the event-driven harness.
    fn next_possible(&self, now: Timestamp) -> Timestamp {
        let interval = self
            .last_decision
            .map_or(now + 1, |t| t + self.cfg.interval);
        let cooldown = self
            .last_rescale
            .map_or(now + 1, |t| t + self.cfg.cooldown);
        interval.max(cooldown).max(now + 1)
    }

    /// The per-operator core: per-stage busy fractions → per-stage true
    /// rates → per-stage minimal parallelisms, with observed output/input
    /// ratios propagating the source rate down the chain. The per-stage
    /// view comes from the incremental [`StageMonitor`] — no hashing, no
    /// window re-reads on decision ticks.
    fn stage_targets(&mut self, view: &SimView<'_>) -> Option<Vec<usize>> {
        let n_stages = view.stage_parallelism.len();
        self.stage_monitor.snapshots_into(
            view.tsdb,
            view.now,
            DS2_WINDOW,
            n_stages,
            &mut self.stage_snaps,
        );
        let snaps = &self.stage_snaps;
        if snaps.len() < n_stages {
            return None;
        }
        let source_rate = view
            .tsdb
            .last_at(&crate::metrics::SeriesId::global("workload_rate"), view.now)
            .map(|(_, v)| v)?;
        let mut demand = source_rate;
        let mut targets = Vec::with_capacity(n_stages);
        for (s, snap) in snaps.iter().enumerate() {
            let n_s = view.stage_parallelism[s].max(1);
            // The staged engine instruments per-stage busy time exactly
            // (as DS2 instruments operator useful-time), so the true rate
            // needs no CPU-range calibration.
            let busy = snap.busy.clamp(0.02, 1.0);
            // Shared finite gates (corruption can leave NaN/∞ samples in
            // the window even after the fault ends): a bad denominator or
            // a bad quota reads as missing instrumentation → hold.
            let per_replica_true = guard::finite_pos((snap.throughput / n_s as f64) / busy)?;
            let quota = guard::finite(self.cfg.headroom * demand / per_replica_true)?;
            let t_s = (quota.ceil() as usize).clamp(self.cfg.min_replicas, self.cfg.max_replicas);
            targets.push(t_s);
            if s + 1 < n_stages {
                // Observed selectivity: downstream input over this input.
                let ratio = if snap.throughput > 1e-9 {
                    (snaps[s + 1].throughput / snap.throughput).clamp(0.01, 20.0)
                } else {
                    1.0
                };
                demand *= ratio;
            }
        }
        Some(targets)
    }
}

impl Autoscaler for Ds2 {
    fn name(&self) -> String {
        "ds2".to_string()
    }

    fn decide(&mut self, view: &SimView<'_>) -> Option<usize> {
        if !self.gate(view) {
            return None;
        }

        self.worker_monitor
            .snapshots_into(view.tsdb, view.now, DS2_WINDOW, &mut self.worker_snaps);
        let snaps = &self.worker_snaps;
        if snaps.is_empty() {
            return None;
        }
        // Calibrate the CPU range from observations.
        let (mut floor, mut ceiling) = (self.idle_floor, self.sat_ceiling);
        for s in snaps {
            floor = floor.min(s.cpu.max(0.01));
            ceiling = ceiling.max(s.cpu);
        }
        self.idle_floor = floor;
        self.sat_ceiling = ceiling;
        let span = (self.sat_ceiling - self.idle_floor).max(0.05);

        // True processing rate per worker = throughput / busy fraction.
        let mut true_rate_sum = 0.0;
        let mut tput_sum = 0.0;
        for s in snaps {
            let busy = ((s.cpu - self.idle_floor) / span).clamp(0.02, 1.0);
            true_rate_sum += s.throughput / busy;
            tput_sum += s.throughput;
        }
        // Shared finite gate: a NaN sum slips through a plain `<= 0.0`
        // comparison (NaN compares false) and would poison the target.
        let avg_true_rate = guard::finite_pos(true_rate_sum / snaps.len() as f64)?;

        // Source rate: what arrives, not what is processed — use the
        // workload metric (DS2 instruments source observed rates).
        let source_rate = guard::finite(
            view.tsdb
                .last_at(&crate::metrics::SeriesId::global("workload_rate"), view.now)
                .map(|(_, v)| v)
                .unwrap_or(tput_sum),
        )?;

        let target = ((self.cfg.headroom * source_rate / avg_true_rate).ceil() as usize)
            .clamp(self.cfg.min_replicas, self.cfg.max_replicas);
        let current = view.parallelism;
        if target.abs_diff(current) < self.cfg.min_delta.max(1) {
            return None;
        }
        self.last_rescale = Some(view.now);
        Some(target)
    }

    fn decide_plan(&mut self, view: &SimView<'_>) -> Option<ScalePlan> {
        // Fused flat pool: the retained job-level formulation.
        if view.stage_parallelism.is_empty() {
            return self.decide(view).map(ScalePlan::Uniform);
        }
        if !self.gate(view) {
            return None;
        }
        let targets = self.stage_targets(view)?;
        let current = view.stage_parallelism;
        let plan = match self.mode {
            Ds2Mode::PerOperator => {
                let delta: usize = targets
                    .iter()
                    .zip(current)
                    .map(|(a, b)| a.abs_diff(*b))
                    .sum();
                if delta < self.cfg.min_delta.max(1) {
                    return None;
                }
                ScalePlan::PerStage(targets)
            }
            Ds2Mode::JobLevel => {
                // Reconfiguration granularity = the whole job: every
                // operator gets the worst operator's requirement.
                let max = targets.iter().copied().max().unwrap_or(1);
                let cur_max = current.iter().copied().max().unwrap_or(1);
                let uniform = current.iter().all(|&c| c == cur_max);
                if max.abs_diff(cur_max) < self.cfg.min_delta.max(1) {
                    // Hysteresis applies against the job level (`cur_max`)
                    // regardless of uniformity — a sub-`min_delta` target
                    // must never churn rescales just because replica
                    // counts drifted apart (per-stage plan, partial
                    // restart). A non-uniform deployment still gets *one*
                    // normalizing plan back to its current job level;
                    // once uniform, the gate holds.
                    if uniform {
                        return None;
                    }
                    ScalePlan::Uniform(cur_max)
                } else {
                    ScalePlan::Uniform(max)
                }
            }
        };
        self.last_rescale = Some(view.now);
        Some(plan)
    }

    fn next_decision(&self, now: Timestamp) -> Timestamp {
        self.next_possible(now)
    }

    /// Exact via the controller's own gate arithmetic: every `decide` on
    /// `(now, next_possible(now))` bails inside [`Ds2::gate`] *before*
    /// touching `last_decision`, and a gate-passing tick mutates state
    /// even when no plan results — so the claim never extends past the
    /// next gate-passing tick, and never covers an unready view.
    fn decide_is_noop_over(&self, view: &SimView<'_>, until: Timestamp) -> bool {
        !view.tsdb.degraded_over(view.now, until)
            && view.ready
            && until <= self.next_possible(view.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{EngineProfile, SimConfig, Simulation};
    use crate::jobs::JobProfile;
    use crate::workload::{ConstantWorkload, StepWorkload};

    /// The replica bound DS2 runs under in the sweep: taken from the
    /// scenario registry's canonical cell instead of a hard-coded constant,
    /// so these tests cannot drift from the registry defaults.
    fn registry_max_replicas() -> usize {
        let reg = crate::experiments::scenarios::ScenarioRegistry::builtin(1_200, &[1]);
        reg.get("flink-wordcount-sine").unwrap().max_replicas
    }

    fn drive(workload: Box<dyn crate::workload::Workload>, secs: u64) -> Simulation {
        let max_replicas = registry_max_replicas();
        let cfg = SimConfig {
            partitions: 36,
            max_replicas,
            seed: 9,
            rate_noise: 0.01,
            ..SimConfig::base(EngineProfile::flink(), JobProfile::wordcount(), workload)
        };
        let mut sim = Simulation::new(cfg);
        let mut ds2 = Ds2::new(Ds2Config::defaults(max_replicas));
        for t in 0..secs {
            sim.step(t);
            if let Some(n) = ds2.decide(&sim.view()) {
                sim.request_rescale(n);
            }
        }
        sim
    }

    #[test]
    fn jumps_directly_to_sufficient_parallelism() {
        // 4 → enough for 35 k in few steps (DS2's "three steps" claim:
        // it converges fast because it computes the target directly).
        let sim = drive(
            Box::new(StepWorkload {
                steps: vec![(0, 8_000.0), (600, 35_000.0)],
                duration: 3_000,
            }),
            3_000,
        );
        assert!(sim.parallelism() >= 7, "p = {}", sim.parallelism());
        // Converged with a bounded number of corrections (catch-up skews
        // the true-rate estimate briefly, so a few oscillations happen).
        assert!(sim.rescale_log.len() <= 10, "{} rescales", sim.rescale_log.len());
    }

    #[test]
    fn scales_in_on_low_load() {
        let sim = drive(
            Box::new(ConstantWorkload {
                rate: 6_000.0,
                duration: 2_400,
            }),
            2_400,
        );
        assert!(sim.parallelism() <= 3, "p = {}", sim.parallelism());
        assert!(sim.total_backlog() < 30_000.0);
    }

    #[test]
    fn holds_during_cooldown_and_restarts() {
        let max = registry_max_replicas();
        let mut ds2 = Ds2::new(Ds2Config::defaults(max));
        let db = crate::metrics::Tsdb::new();
        let view = SimView {
            now: 100,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 4,
            ready: false,
            max_replicas: max,
            stage_parallelism: &[],
            dropped_rescales: 0,
        };
        assert_eq!(ds2.decide(&view), None);
        assert_eq!(ds2.decide_plan(&view), None);
    }

    /// Hand-built staged metrics: three stages where the middle one is the
    /// bottleneck. The per-operator formulation must target each stage
    /// individually; the job-level mode must apply the max uniformly.
    fn staged_db() -> crate::metrics::Tsdb {
        staged_db_upto(200)
    }

    fn staged_db_upto(upto: u64) -> crate::metrics::Tsdb {
        let mut db = crate::metrics::Tsdb::new();
        for t in 0..upto {
            db.record_global("workload_rate", t, 10_000.0);
            // Stage 0: source, 10k in, busy 0.25 at 2 replicas
            //   → per-replica true rate 20k → needs 1.
            db.record_stage("stage_throughput", 0, t, 10_000.0);
            db.record_stage("stage_busy", 0, t, 0.25);
            db.record_stage("stage_parallelism", 0, t, 2.0);
            db.record_stage("stage_queue", 0, t, 0.0);
            // Stage 1: flat-map ×3 output, 10k in, busy 0.8 at 2 replicas
            //   → per-replica true 6.25k → needs ceil(1.1·10k/6.25k) = 2.
            db.record_stage("stage_throughput", 1, t, 10_000.0);
            db.record_stage("stage_busy", 1, t, 0.8);
            db.record_stage("stage_parallelism", 1, t, 2.0);
            db.record_stage("stage_queue", 1, t, 50.0);
            // Stage 2: 30k in (sel 3), busy 1.0 at 2 replicas
            //   → per-replica true 15k → needs ceil(1.1·30k/15k) = 3.
            db.record_stage("stage_throughput", 2, t, 30_000.0);
            db.record_stage("stage_busy", 2, t, 1.0);
            db.record_stage("stage_parallelism", 2, t, 2.0);
            db.record_stage("stage_queue", 2, t, 5_000.0);
        }
        db
    }

    #[test]
    fn per_operator_mode_emits_stage_vector() {
        let db = staged_db();
        let mut ds2 = Ds2::new(Ds2Config::defaults(12));
        let stage_par = [2usize, 2, 2];
        let view = SimView {
            now: 199,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 2,
            ready: true,
            max_replicas: 12,
            stage_parallelism: &stage_par,
            dropped_rescales: 0,
        };
        let plan = ds2.decide_plan(&view).expect("per-stage plan");
        assert_eq!(plan, ScalePlan::PerStage(vec![1, 2, 3]));
    }

    #[test]
    fn job_level_mode_applies_max_uniformly() {
        let db = staged_db();
        let mut ds2 = Ds2::job_level(Ds2Config::defaults(12));
        let stage_par = [2usize, 2, 2];
        let view = SimView {
            now: 199,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 2,
            ready: true,
            max_replicas: 12,
            stage_parallelism: &stage_par,
            dropped_rescales: 0,
        };
        let plan = ds2.decide_plan(&view).expect("uniform plan");
        assert_eq!(plan, ScalePlan::Uniform(3));
    }

    #[test]
    fn job_level_hysteresis_holds_regardless_of_uniformity() {
        // Non-uniform deployment whose job-level target is within
        // `min_delta` of the current job level: exactly one normalizing
        // plan back to `cur_max`, then the gate holds — no back-to-back
        // sub-threshold rescales.
        let db = staged_db_upto(600);
        let cfg = Ds2Config {
            min_delta: 2,
            ..Ds2Config::defaults(12)
        };
        let mut ds2 = Ds2::job_level(cfg.clone());
        let drifted = [2usize, 3, 2]; // drifted apart; job level = 3
        let view = SimView {
            now: 199,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 3,
            ready: true,
            max_replicas: 12,
            stage_parallelism: &drifted,
            dropped_rescales: 0,
        };
        // Targets max = 3 = cur_max (sub-threshold) → one normalizing plan.
        assert_eq!(ds2.decide_plan(&view), Some(ScalePlan::Uniform(3)));
        // Plan applied → uniform. Past interval + cooldown the gate passes
        // again, but the sub-`min_delta` delta now holds: no second plan.
        let uniform_par = [3usize, 3, 3];
        let view2 = SimView {
            now: 580,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 3,
            ready: true,
            max_replicas: 12,
            stage_parallelism: &uniform_par,
            dropped_rescales: 0,
        };
        assert_eq!(ds2.decide_plan(&view2), None);

        // The normalizing plan targets the *current* job level, never a
        // sub-threshold new one: with targets max = 4 vs cur_max = 3
        // (|Δ| = 1 < min_delta = 2) the old behavior emitted Uniform(4)
        // every loop tick while the deployment stayed non-uniform.
        let mut ds2b = Ds2::job_level(cfg);
        let drifted_up = [2usize, 3, 3]; // stage-2 target rises to 4
        let view3 = SimView {
            now: 199,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 3,
            ready: true,
            max_replicas: 12,
            stage_parallelism: &drifted_up,
            dropped_rescales: 0,
        };
        assert_eq!(ds2b.decide_plan(&view3), Some(ScalePlan::Uniform(3)));
    }
}
