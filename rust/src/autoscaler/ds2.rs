//! DS2-style reactive autoscaler (Kalavri et al., OSDI '18) — the paper's
//! §2 "three steps is all you need" comparison point.
//!
//! DS2 computes each operator's *true processing rate* (tuples/s of pure
//! processing, excluding idle/back-pressure time) and jumps directly to the
//! minimal parallelism whose aggregate true rate covers the observed source
//! rate. It is purely reactive (no forecasting), assumes **no data skew**
//! (scales by averages), and assumes the workload holds still while it
//! converges — exactly the limitations Daedalus targets (§2).
//!
//! Mapping to our observables: a worker's busy fraction is
//! `(cpu − idle) / (cpu_sat − idle)`; its true rate is
//! `throughput / busy_fraction`. We estimate `idle`/`cpu_sat` conservatively
//! from the observed CPU range, as DS2 instruments its runtimes to do.

use super::Autoscaler;
use crate::clock::Timestamp;
use crate::dsp::engine::SimView;
use crate::metrics::query::worker_snapshots;

/// DS2 tuning.
#[derive(Debug, Clone)]
pub struct Ds2Config {
    /// Decision interval (seconds) — DS2 evaluates on policy windows.
    pub interval: u64,
    /// Activation threshold: rescale only if the target differs from the
    /// current parallelism by at least this many workers.
    pub min_delta: usize,
    /// Headroom factor on the computed minimum (DS2's ρ ≈ utilization cap).
    pub headroom: f64,
    /// Cooldown after a rescale (convergence wait).
    pub cooldown: u64,
    pub min_replicas: usize,
    pub max_replicas: usize,
}

impl Ds2Config {
    pub fn defaults(max_replicas: usize) -> Self {
        Self {
            interval: 60,
            min_delta: 1,
            headroom: 1.1,
            cooldown: 180,
            min_replicas: 1,
            max_replicas,
        }
    }
}

/// The DS2-like controller.
pub struct Ds2 {
    cfg: Ds2Config,
    last_decision: Option<Timestamp>,
    last_rescale: Option<Timestamp>,
    /// Running estimate of the idle-CPU floor (min CPU ever seen).
    idle_floor: f64,
    /// Running estimate of the saturation ceiling (max CPU ever seen).
    sat_ceiling: f64,
}

impl Ds2 {
    pub fn new(cfg: Ds2Config) -> Self {
        Self {
            cfg,
            last_decision: None,
            last_rescale: None,
            idle_floor: 0.05,
            sat_ceiling: 0.5,
        }
    }
}

impl Autoscaler for Ds2 {
    fn name(&self) -> String {
        "ds2".to_string()
    }

    fn decide(&mut self, view: &SimView<'_>) -> Option<usize> {
        if !view.ready {
            return None;
        }
        if let Some(t) = self.last_decision {
            if view.now < t + self.cfg.interval {
                return None;
            }
        }
        if let Some(t) = self.last_rescale {
            if view.now < t + self.cfg.cooldown {
                return None;
            }
        }
        self.last_decision = Some(view.now);

        let snaps = worker_snapshots(view.tsdb, view.now, 60);
        if snaps.is_empty() {
            return None;
        }
        // Calibrate the CPU range from observations.
        for s in &snaps {
            self.idle_floor = self.idle_floor.min(s.cpu.max(0.01));
            self.sat_ceiling = self.sat_ceiling.max(s.cpu);
        }
        let span = (self.sat_ceiling - self.idle_floor).max(0.05);

        // True processing rate per worker = throughput / busy fraction.
        let mut true_rate_sum = 0.0;
        let mut tput_sum = 0.0;
        for s in &snaps {
            let busy = ((s.cpu - self.idle_floor) / span).clamp(0.02, 1.0);
            true_rate_sum += s.throughput / busy;
            tput_sum += s.throughput;
        }
        let avg_true_rate = true_rate_sum / snaps.len() as f64;
        if avg_true_rate <= 0.0 {
            return None;
        }

        // Source rate: what arrives, not what is processed — use the
        // workload metric (DS2 instruments source observed rates).
        let source_rate = view
            .tsdb
            .last_at(&crate::metrics::SeriesId::global("workload_rate"), view.now)
            .map(|(_, v)| v)
            .unwrap_or(tput_sum);

        let target = ((self.cfg.headroom * source_rate / avg_true_rate).ceil() as usize)
            .clamp(self.cfg.min_replicas, self.cfg.max_replicas);
        let current = view.parallelism;
        if target.abs_diff(current) < self.cfg.min_delta.max(1) {
            return None;
        }
        self.last_rescale = Some(view.now);
        Some(target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{EngineProfile, SimConfig, Simulation};
    use crate::jobs::JobProfile;
    use crate::workload::{ConstantWorkload, StepWorkload};

    fn drive(workload: Box<dyn crate::workload::Workload>, secs: u64) -> Simulation {
        let cfg = SimConfig {
            profile: EngineProfile::flink(),
            job: JobProfile::wordcount(),
            workload,
            partitions: 36,
            initial_replicas: 4,
            max_replicas: 12,
            seed: 9,
            rate_noise: 0.01,
            failures: vec![],
        };
        let mut sim = Simulation::new(cfg);
        let mut ds2 = Ds2::new(Ds2Config::defaults(12));
        for t in 0..secs {
            sim.step(t);
            if let Some(n) = ds2.decide(&sim.view()) {
                sim.request_rescale(n);
            }
        }
        sim
    }

    #[test]
    fn jumps_directly_to_sufficient_parallelism() {
        // 4 → enough for 35 k in few steps (DS2's "three steps" claim:
        // it converges fast because it computes the target directly).
        let sim = drive(
            Box::new(StepWorkload {
                steps: vec![(0, 8_000.0), (600, 35_000.0)],
                duration: 3_000,
            }),
            3_000,
        );
        assert!(sim.parallelism() >= 7, "p = {}", sim.parallelism());
        // Converged with a bounded number of corrections (catch-up skews
        // the true-rate estimate briefly, so a few oscillations happen).
        assert!(sim.rescale_log.len() <= 10, "{} rescales", sim.rescale_log.len());
    }

    #[test]
    fn scales_in_on_low_load() {
        let sim = drive(
            Box::new(ConstantWorkload {
                rate: 6_000.0,
                duration: 2_400,
            }),
            2_400,
        );
        assert!(sim.parallelism() <= 3, "p = {}", sim.parallelism());
        assert!(sim.total_backlog() < 30_000.0);
    }

    #[test]
    fn holds_during_cooldown_and_restarts() {
        let mut ds2 = Ds2::new(Ds2Config::defaults(12));
        let db = crate::metrics::Tsdb::new();
        let view = SimView {
            now: 100,
            tsdb: &db,
            parallelism: 4,
            ready: false,
            max_replicas: 12,
        };
        assert_eq!(ds2.decide(&view), None);
    }
}
