//! Autoscalers: Daedalus (the paper's contribution) and the comparison
//! systems it is evaluated against (§4.3).
//!
//! * [`daedalus`] — the self-adaptive MAPE-K manager (§3).
//! * [`hpa`] — Kubernetes Horizontal Pod Autoscaler semantics (§4.3.2).
//! * [`ds2`] — DS2-style reactive true-rate scaler (related work, §2).
//! * [`statik`] — fixed scale-out baseline (§4.3.1).
//! * [`phoebe`] — profiling-based QoS-model autoscaler (§4.3.3).
//!
//! All implement [`Autoscaler`]: once per tick they see the metric store
//! and may request a replica count; the engine turns requests into
//! stop-the-world restarts.

pub mod daedalus;
pub mod ds2;
pub mod hpa;
pub mod phoebe;
pub mod statik;

pub use daedalus::{Daedalus, DaedalusConfig};
pub use ds2::{Ds2, Ds2Config};
pub use hpa::{Hpa, HpaConfig};
pub use phoebe::{Phoebe, PhoebeConfig};
pub use statik::Static;

use crate::clock::Timestamp;
use crate::dsp::engine::{ScalePlan, SimView};

/// A horizontal autoscaling policy.
pub trait Autoscaler {
    /// Display name for reports ("daedalus", "hpa-80", …).
    fn name(&self) -> String;

    /// Called once per simulated second with the current metric view.
    /// Returning `Some(n)` requests a rescale to `n` replicas; the engine
    /// ignores requests equal to the current parallelism or mid-restart.
    fn decide(&mut self, view: &SimView<'_>) -> Option<usize>;

    /// Called once per simulated second by the harness. Job-level
    /// autoscalers inherit this uniform-vector adapter: their single
    /// parallelism is applied to every operator stage (Flink reactive-mode
    /// semantics) or to the fused pool. Per-operator autoscalers (DS2,
    /// Daedalus on a staged deployment) override it to emit
    /// [`ScalePlan::PerStage`] vectors.
    fn decide_plan(&mut self, view: &SimView<'_>) -> Option<ScalePlan> {
        self.decide(view).map(ScalePlan::Uniform)
    }

    /// Whether the harness should complete a checkpoint immediately before
    /// applying this scaler's rescale (Phoebe's manual pre-scale
    /// checkpoint, §4.8).
    fn wants_precheckpoint(&self) -> bool {
        false
    }

    /// Earliest future tick (strictly after `now`, the tick whose
    /// `decide`/`decide_plan` call just returned) at which this scaler
    /// could *possibly* act. The event-driven harness uses this to bound
    /// quiet spans: every `decide` call at a steady-state tick in
    /// `(now, next_decision(now))` is guaranteed to be a pure no-op
    /// (returns `None`, mutates no internal state), so those calls may be
    /// skipped wholesale. Scalers with per-tick background work (Daedalus'
    /// anomaly tracking) must replay the skipped ticks themselves from the
    /// dense TSDB when their next decision fires.
    ///
    /// The conservative default — a decision possible every tick —
    /// disables span skipping for scalers that don't opt in.
    fn next_decision(&self, now: Timestamp) -> Timestamp {
        now + 1
    }
}
