//! Autoscalers: Daedalus (the paper's contribution) and the comparison
//! systems it is evaluated against (§4.3).
//!
//! * [`daedalus`] — the self-adaptive MAPE-K manager (§3).
//! * [`demeter`] — Daedalus plus runtime-config co-optimization
//!   (Demeter-class multi-configuration tuning, PAPERS.md).
//! * [`hpa`] — Kubernetes Horizontal Pod Autoscaler semantics (§4.3.2).
//! * [`ds2`] — DS2-style reactive true-rate scaler (related work, §2).
//! * [`statik`] — fixed scale-out baseline (§4.3.1).
//! * [`phoebe`] — profiling-based QoS-model autoscaler (§4.3.3).
//!
//! All implement [`Autoscaler`]: once per tick they see the metric store
//! and may request a replica count; the engine turns requests into
//! stop-the-world restarts.

pub mod daedalus;
pub mod demeter;
pub mod ds2;
pub mod guard;
pub mod hpa;
pub mod phoebe;
pub mod statik;

pub use daedalus::{Daedalus, DaedalusConfig};
pub use demeter::{Demeter, DemeterConfig};
pub use ds2::{Ds2, Ds2Config};
pub use hpa::{Hpa, HpaConfig};
pub use phoebe::{Phoebe, PhoebeConfig};
pub use statik::Static;

use crate::clock::Timestamp;
use crate::dsp::engine::{RuntimeConfig, ScalePlan, SimView};

/// A horizontal autoscaling policy.
pub trait Autoscaler {
    /// Display name for reports ("daedalus", "hpa-80", …).
    fn name(&self) -> String;

    /// Called once per simulated second with the current metric view.
    /// Returning `Some(n)` requests a rescale to `n` replicas; the engine
    /// ignores requests equal to the current parallelism or mid-restart.
    fn decide(&mut self, view: &SimView<'_>) -> Option<usize>;

    /// Called once per simulated second by the harness. Job-level
    /// autoscalers inherit this uniform-vector adapter: their single
    /// parallelism is applied to every operator stage (Flink reactive-mode
    /// semantics) or to the fused pool. Per-operator autoscalers (DS2,
    /// Daedalus on a staged deployment) override it to emit
    /// [`ScalePlan::PerStage`] vectors.
    fn decide_plan(&mut self, view: &SimView<'_>) -> Option<ScalePlan> {
        self.decide(view).map(ScalePlan::Uniform)
    }

    /// Whether the harness should complete a checkpoint immediately before
    /// applying this scaler's rescale (Phoebe's manual pre-scale
    /// checkpoint, §4.8).
    fn wants_precheckpoint(&self) -> bool {
        false
    }

    /// Earliest future tick (strictly after `now`, the tick whose
    /// `decide`/`decide_plan` call just returned) at which this scaler
    /// could *possibly* act. The event-driven harness uses this to bound
    /// quiet spans: every `decide` call at a steady-state tick in
    /// `(now, next_decision(now))` is guaranteed to be a pure no-op
    /// (returns `None`, mutates no internal state), so those calls may be
    /// skipped wholesale. Scalers with per-tick background work (Daedalus'
    /// anomaly tracking) must replay the skipped ticks themselves from the
    /// dense TSDB when their next decision fires.
    ///
    /// The conservative default — a decision possible every tick —
    /// disables span skipping for scalers that don't opt in.
    fn next_decision(&self, now: Timestamp) -> Timestamp {
        now + 1
    }

    /// Whether every `decide`/`decide_plan` call on the steady span
    /// `(view.now, until)` is *provably* a pure no-op — returns no plan
    /// and mutates no internal state — given the steady-state `view`
    /// (constant rate, constant parallelism, ready, no backlog) that the
    /// event-driven harness observes at span start. When this returns
    /// `true` the harness lets a quiet span run through those decision
    /// ticks without waking the scaler.
    ///
    /// Same safety rule as [`Self::next_decision`] (CONTRIBUTING item 4's
    /// boundary hooks): the predicate must be a *pure* function of the
    /// scaler's own gate arithmetic, conservative-`false` whenever the
    /// answer needs anything not provably constant over the span. The
    /// default delegates to [`Self::next_decision`] — exact for scalers
    /// whose gates are purely time-based, conservative for the rest —
    /// AND refuses the span whenever a telemetry fault window intersects
    /// it: degraded reads can flip guard state (safe-mode holds,
    /// cooldowns) at ticks the gate arithmetic alone would call quiet, so
    /// the harness must step those ticks densely to keep
    /// EventDriven ≡ PerTick bitwise. Clean runs are unaffected (the
    /// predicate is `false`-only-wider, and an empty timeline never
    /// intersects). Overrides must keep this conjunct.
    fn decide_is_noop_over(&self, view: &SimView<'_>, until: Timestamp) -> bool {
        !view.tsdb.degraded_over(view.now, until) && until <= self.next_decision(view.now)
    }

    /// Called once per simulated second immediately after
    /// [`Self::decide_plan`], in both engine modes at the same ticks.
    /// Returning `Some(config)` asks the harness to stage a
    /// [`RuntimeConfig`] via `Simulation::request_reconfigure`; it takes
    /// effect at the next consistent cut. Scale-out-only policies inherit
    /// the `None` default and never reconfigure. Scalers that override
    /// this must also make [`Self::decide_is_noop_over`] refuse any span
    /// over which a reconfigure proposal could fire, or the event-driven
    /// harness will skip the tick that was supposed to emit it.
    fn decide_reconfigure(&mut self, view: &SimView<'_>) -> Option<RuntimeConfig> {
        let _ = view;
        None
    }
}
