//! Analyze phase, forecasting half (§3.3): run the AOT forecast artifact,
//! gate its quality with WAPE against realized workload, fall back to a
//! linear projection when the previous forecast was poor, and count
//! consecutive poor forecasts toward a retrain.

use crate::autoscaler::guard;
use crate::clock::Timestamp;
use crate::runtime::ComputeBackend;
use crate::stats::{wape, HoltWinters, LinearRegression};

use super::knowledge::{IssuedForecast, Knowledge};
use super::monitor::MonitorData;
use super::DaedalusConfig;

/// Which forecaster produces the 15-minute prediction (ablation §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForecastMethod {
    /// The AOT subset-ARI(p,1) artifact (the paper's ARIMA-class default).
    ArtifactAr,
    /// Holt's damped-trend exponential smoothing (native).
    HoltWinters,
    /// Linear-regression projection only (the fallback as the main model).
    Linear,
    /// No anticipation: flat continuation of the last observation
    /// (turns Daedalus into a purely reactive scaler).
    Flat,
}

/// Seconds of history the linear fallback is fitted on.
const FALLBACK_FIT_WINDOW: usize = 300;
/// Minimum overlap before a WAPE evaluation is meaningful.
const MIN_WAPE_OVERLAP: usize = 30;

/// Forecast handed to the plan phase.
#[derive(Debug, Clone)]
pub struct ForecastResult {
    /// Predicted workload for the next `horizon` seconds (non-negative).
    pub values: Vec<f64>,
    /// True if from the ARI artifact, false if the linear fallback.
    pub from_model: bool,
    /// WAPE of the previous forecast vs. realized workload, if evaluable.
    pub prev_wape: Option<f64>,
}

/// Produce this iteration's forecast (and do the quality bookkeeping).
pub fn forecast(
    backend: &ComputeBackend,
    knowledge: &mut Knowledge,
    data: &MonitorData,
    cfg: &DaedalusConfig,
    now: Timestamp,
) -> ForecastResult {
    let meta = backend.meta();

    // 1. Score the previous forecast against what actually happened.
    let mut prev_wape = None;
    let mut use_fallback = false;
    if let Some(prev) = &knowledge.last_forecast {
        let elapsed = now.saturating_sub(prev.issued_at) as usize;
        let k = elapsed.min(prev.values.len());
        if k >= MIN_WAPE_OVERLAP && data.history.len() >= k {
            let actual = &data.history[data.history.len() - k..];
            // Hardened: corrupted samples (NaN/∞) can linger in the
            // realized window after a telemetry fault ends; a single one
            // would poison the WAPE score and, through the streak counter,
            // the retrain bookkeeping. Refuse the evaluation instead —
            // "not evaluable", exactly like insufficient overlap.
            let finite_actual =
                !cfg.hardened || actual.iter().all(|&v| guard::finite(v).is_some());
            if let Some(w) = finite_actual
                .then(|| wape(actual, &prev.values[..k]))
                .flatten()
            {
                knowledge.wape_history.push(w);
                prev_wape = Some(w);
                if w > cfg.wape_threshold {
                    use_fallback = true;
                    knowledge.bad_forecast_streak += 1;
                    if knowledge.bad_forecast_streak >= cfg.retrain_streak {
                        // §3.3: retrain in the background. Our subset-AR is
                        // refit from the full window every loop, so the
                        // retrain amounts to dropping the streak; we count
                        // it for §4.8-style reporting.
                        knowledge.retrain_count += 1;
                        knowledge.bad_forecast_streak = 0;
                    }
                } else {
                    knowledge.bad_forecast_streak = 0;
                }
            }
        }
    }

    // 1b. Warm-up gate: with less real history than the AR's longest lag
    // (the window is left-padded with the first sample), the standardized
    // differences degenerate and the fit is meaningless — use the linear
    // fallback until enough history exists (the paper trains the initial
    // model "with the available workload").
    if (now as usize) < meta.max_lag + 2 * cfg.loop_interval as usize {
        use_fallback = true;
    }

    // 2. Model forecast (method per config; the artifact is the default).
    let model_values: Option<Vec<f64>> = match cfg.forecast_method {
        ForecastMethod::ArtifactAr => {
            let hist32: Vec<f32> = data.history.iter().map(|v| *v as f32).collect();
            backend.forecast(&hist32).ok().map(|out| out.clamped())
        }
        ForecastMethod::HoltWinters => {
            Some(HoltWinters::default().forecast(&data.history, meta.horizon))
        }
        ForecastMethod::Linear => Some(linear_fallback(&data.history, meta.horizon)),
        ForecastMethod::Flat => Some(vec![
            data.history.last().copied().unwrap_or(0.0).max(0.0);
            meta.horizon
        ]),
    };

    // 3. Select model vs. fallback (§3.3: the fallback replaces the model
    //    only when the previous prediction was poor).
    let (values, from_model) = match (model_values, use_fallback) {
        (Some(v), false) => (v, true),
        _ => (linear_fallback(&data.history, meta.horizon), false),
    };

    knowledge.last_forecast = Some(IssuedForecast {
        issued_at: now,
        values: values.clone(),
        from_model,
    });
    ForecastResult {
        values,
        from_model,
        prev_wape,
    }
}

/// The paper's fallback: slope of the latest observations projected ahead.
pub fn linear_fallback(history: &[f64], horizon: usize) -> Vec<f64> {
    let n = history.len();
    let fit = &history[n.saturating_sub(FALLBACK_FIT_WINDOW)..];
    match LinearRegression::fit_series(fit) {
        Some(lr) => lr.project(fit.len(), horizon),
        None => vec![history.last().copied().unwrap_or(0.0).max(0.0); horizon],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactMeta;

    fn data(history: Vec<f64>, now: Timestamp) -> MonitorData {
        MonitorData {
            now,
            history,
            parallelism: 4,
            ..MonitorData::empty()
        }
    }

    fn setup() -> (ComputeBackend, Knowledge, DaedalusConfig) {
        let backend = ComputeBackend::native();
        let k = Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0);
        (backend, k, DaedalusConfig::default())
    }

    #[test]
    fn model_forecast_used_when_no_history_of_failure() {
        let (backend, mut k, cfg) = setup();
        let d = data(vec![20_000.0; 1800], 1800);
        let f = forecast(&backend, &mut k, &d, &cfg, 1800);
        assert!(f.from_model);
        assert_eq!(f.values.len(), 900);
        // Constant history → roughly constant forecast.
        assert!((f.values[899] - 20_000.0).abs() < 500.0);
    }

    #[test]
    fn bad_previous_forecast_triggers_fallback() {
        let (backend, mut k, cfg) = setup();
        // Previous forecast said 50k; reality is 10k → WAPE = 4.
        k.last_forecast = Some(IssuedForecast {
            issued_at: 1740,
            values: vec![50_000.0; 900],
            from_model: true,
        });
        let d = data(vec![10_000.0; 1800], 1800);
        let f = forecast(&backend, &mut k, &d, &cfg, 1800);
        assert!(!f.from_model, "should use fallback");
        assert!(f.prev_wape.unwrap() > 3.0);
        assert_eq!(k.bad_forecast_streak, 1);
        // Fallback on a flat series ≈ flat.
        assert!((f.values[0] - 10_000.0).abs() < 200.0);
    }

    #[test]
    fn good_previous_forecast_resets_streak() {
        let (backend, mut k, cfg) = setup();
        k.bad_forecast_streak = 7;
        k.last_forecast = Some(IssuedForecast {
            issued_at: 1740,
            values: vec![10_000.0; 900],
            from_model: true,
        });
        let d = data(vec![10_000.0; 1800], 1800);
        let f = forecast(&backend, &mut k, &d, &cfg, 1800);
        assert!(f.from_model);
        assert_eq!(k.bad_forecast_streak, 0);
        assert!(f.prev_wape.unwrap() < 0.01);
    }

    #[test]
    fn retrain_after_streak() {
        let (backend, mut k, mut cfg) = setup();
        cfg.retrain_streak = 3;
        for i in 0..3 {
            k.last_forecast = Some(IssuedForecast {
                issued_at: 1740,
                values: vec![99_000.0; 900],
                from_model: true,
            });
            let d = data(vec![10_000.0; 1800], 1800);
            forecast(&backend, &mut k, &d, &cfg, 1800);
            if i < 2 {
                assert_eq!(k.retrain_count, 0);
            }
        }
        assert_eq!(k.retrain_count, 1);
        assert_eq!(k.bad_forecast_streak, 0);
    }

    #[test]
    fn fallback_projects_trend() {
        let hist: Vec<f64> = (0..1800).map(|i| 1_000.0 + 10.0 * i as f64).collect();
        let proj = linear_fallback(&hist, 100);
        // Slope 10/s continues.
        assert!((proj[0] - (1_000.0 + 10.0 * 1800.0)).abs() < 50.0);
        assert!(proj[99] > proj[0]);
    }

    #[test]
    fn forecasts_are_nonnegative() {
        let (backend, mut k, cfg) = setup();
        let hist: Vec<f64> = (0..1800).map(|i| (3_000.0 - 2.0 * i as f64).max(0.0)).collect();
        let d = data(hist, 1800);
        let f = forecast(&backend, &mut k, &d, &cfg, 1800);
        assert!(f.values.iter().all(|v| *v >= 0.0));
    }
}
