//! Daedalus — the paper's self-adaptive MAPE-K autoscaling manager (§3).
//!
//! Every `loop_interval` seconds (60 s in the paper) the manager runs:
//!
//! * **Monitor** ([`monitor`]) — per-worker CPU/throughput (1-min moving
//!   averages), total consumer lag, current parallelism, and the workload
//!   observed since the last iteration, all from the TSDB.
//! * **Analyze** ([`analyze`], [`forecasting`]) — per-worker CPU↔throughput
//!   regression capacity models updated through the **AOT capacity
//!   artifact** (Welford fold + prediction at the skew-aware CPU target),
//!   capacity estimates for every scale-out, and a 15-minute workload
//!   forecast through the **AOT forecast artifact**, WAPE-gated with a
//!   linear fallback and retrain counter (§3.3).
//! * **Plan** ([`plan`]) — Algorithm 1: the smallest scale-out that covers
//!   the observed and predicted workload and recovers within the target
//!   recovery time ([`recovery`]), with consumer-lag scale-in protection.
//! * **Execute** — request the rescale and monitor the actual recovery with
//!   statistical anomaly detection ([`anomaly`]), adaptively refining the
//!   assumed downtimes.
//!
//! Knowledge ([`knowledge`]) is the state shared between phases.
//!
//! On a staged deployment ([`crate::dsp::StageModel::Staged`]) the same
//! loop runs per-operator: the monitor collects per-stage busy/throughput
//! snapshots, knowledge keeps a `(stage, replicas) → capacity` ledger of
//! observed estimates, and the plan phase
//! ([`plan::plan_stage_scale_out`]) emits a *vector* of stage
//! parallelisms — minimal per-stage coverage, the recovery-time constraint
//! enforced by growing the bottleneck stage, and the consumer-lag guard
//! applied to net scale-ins.

pub mod analyze;
pub mod anomaly;
pub mod forecasting;
pub mod knowledge;
pub mod monitor;
pub mod plan;
pub mod recovery;

use super::{guard, Autoscaler};
use crate::dsp::engine::{ScalePlan, SimView};
use crate::runtime::ComputeBackend;

use analyze::Analyzer;
use anomaly::RecoveryMonitor;
use knowledge::Knowledge;
use monitor::MonitorData;

/// Tunables (paper defaults).
#[derive(Debug, Clone)]
pub struct DaedalusConfig {
    /// MAPE-K loop interval (seconds).
    pub loop_interval: u64,
    /// Target recovery time (seconds) — 600 in the evaluation.
    pub recovery_target: f64,
    /// Forecast-quality gate: WAPE above this uses the linear fallback.
    pub wape_threshold: f64,
    /// Consecutive poor forecasts before a retrain (§3.3).
    pub retrain_streak: usize,
    /// Grace period after any scaling action (seconds; 3 min in §3.2).
    pub grace_period: u64,
    /// "Long-lived decision" window of Algorithm 1 (600 s).
    pub long_lived_window: u64,
    /// CPU level the hottest worker is extrapolated to (1.0 = theoretical
    /// maximum capacity, §3.1).
    pub cpu_target: f64,
    /// Initial anticipated downtime for scale-out / scale-in (§3.4).
    pub initial_downtime_out: f64,
    /// Initial anticipated downtime for scale-in (§3.4).
    pub initial_downtime_in: f64,
    /// CPU moving-average window for monitor (seconds).
    pub cpu_window: u64,
    /// Don't act before this much history exists.
    pub warmup: u64,
    // --- Ablation switches (all true/ArtifactAr = the paper's Daedalus) ---
    /// Which forecaster feeds the plan phase (§3.3).
    pub forecast_method: forecasting::ForecastMethod,
    /// Enforce the recovery-time constraint in Algorithm 1 (§3.4).
    pub use_recovery_constraint: bool,
    /// Skew-aware per-worker CPU targets (§3.1, Fig 4); off = every worker
    /// extrapolated to the same CPU (the assumption most prior work makes).
    pub skew_aware: bool,
    /// Consumer-lag scale-in protection (§3.2).
    pub use_lag_guard: bool,
    /// Degraded-telemetry hardening: hold the last plan while a telemetry
    /// fault is visible, quarantine capacity observations collected under
    /// corruption/staleness from the knowledge ledger, refuse non-finite
    /// history into the forecaster's WAPE gate, and clamp the first
    /// post-recovery rescale through a [`guard::PlanGuard`] cooldown.
    /// `false` is the unguarded ablation: the exact pre-hardening manager,
    /// reading whatever the (possibly faulted) lens serves.
    pub hardened: bool,
    /// Read capacity from the config-keyed `(stage, replicas, fingerprint)`
    /// ledger when a cell exists (ISSUE 10). Off for the paper's Daedalus —
    /// the ledger is still *written* (so a later config-aware planner can
    /// warm-start from it), but plans stay bit-identical to the
    /// config-agnostic manager.
    pub use_config_ledger: bool,
    /// Checkpoint interval the staged plan phase assumes for the
    /// replay-backlog worst case. [`plan::CHECKPOINT_INTERVAL`] (the job's
    /// configured 10 s) for the fixed-config manager; config-aware wrappers
    /// keep this in sync with the *active* [`crate::dsp::RuntimeConfig`] so
    /// the recovery constraint prices replay at its true size.
    pub plan_checkpoint_interval: u64,
}

impl Default for DaedalusConfig {
    fn default() -> Self {
        Self {
            loop_interval: 60,
            recovery_target: 600.0,
            wape_threshold: 0.25,
            retrain_streak: 15,
            grace_period: 180,
            long_lived_window: 600,
            cpu_target: 1.0,
            initial_downtime_out: 30.0,
            initial_downtime_in: 15.0,
            cpu_window: 60,
            warmup: 120,
            forecast_method: forecasting::ForecastMethod::ArtifactAr,
            use_recovery_constraint: true,
            skew_aware: true,
            use_lag_guard: true,
            hardened: true,
            use_config_ledger: false,
            plan_checkpoint_interval: plan::CHECKPOINT_INTERVAL,
        }
    }
}

/// Largest parallelism step the [`guard::PlanGuard`] allows on the first
/// decision after a degraded-telemetry hold (workers per decision).
const GUARD_MAX_STEP: usize = 2;
/// Post-hold cooldown (seconds) during which the step clamp applies.
const GUARD_COOLDOWN: u64 = 120;

/// The self-adaptive manager.
pub struct Daedalus {
    /// Loop configuration (public for the ablation variants).
    pub cfg: DaedalusConfig,
    backend: ComputeBackend,
    knowledge: Knowledge,
    analyzer: Analyzer,
    recovery_monitor: Option<RecoveryMonitor>,
    /// Post-degradation sanity clamp on plan output (hardened mode only;
    /// state mutates exclusively at degraded ticks, which the harness
    /// steps densely — so it is bitwise identical across engine modes).
    plan_guard: guard::PlanGuard,
    next_loop: u64,
    /// First tick the per-second background threads (anomaly statistics,
    /// recovery monitoring) have *not* yet processed. The event-driven
    /// harness may skip `decide` calls inside quiet spans; `loop_gate`
    /// replays every tick in `tracked_until..=now` from the dense TSDB so
    /// the Welford statistics and recovery observations are bit-identical
    /// to per-tick operation.
    tracked_until: u64,
    /// Reusable monitor-phase buffer (worker snapshots + workload history
    /// keep their capacity across iterations — no per-loop allocation).
    monitor_buf: MonitorData,
}

impl Daedalus {
    /// Manager with fresh knowledge on the given compute backend.
    pub fn new(cfg: DaedalusConfig, backend: ComputeBackend) -> Self {
        let meta = backend.meta().clone();
        Self {
            knowledge: Knowledge::new(&meta, cfg.initial_downtime_out, cfg.initial_downtime_in),
            analyzer: Analyzer::new(meta),
            recovery_monitor: None,
            plan_guard: guard::PlanGuard::new(GUARD_MAX_STEP, GUARD_COOLDOWN),
            next_loop: cfg.warmup,
            tracked_until: 0,
            cfg,
            backend,
            monitor_buf: MonitorData::empty(),
        }
    }

    /// Access to the knowledge base (reports, tests).
    pub fn knowledge(&self) -> &Knowledge {
        &self.knowledge
    }

    /// Mutable knowledge access for sibling-module unit tests.
    #[cfg(test)]
    pub(crate) fn knowledge_mut(&mut self) -> &mut Knowledge {
        &mut self.knowledge
    }

    /// Tell the knowledge base which runtime config the deployment is
    /// currently running under: subsequent capacity observations land in
    /// (and config-aware reads come from) the matching
    /// `(stage, replicas, fingerprint)` cells. Called by config-aware
    /// wrappers (demeter) whenever a reconfigure is applied; the
    /// fixed-config manager never calls it, leaving the fingerprint at 0.
    pub fn set_active_config_fingerprint(&mut self, fingerprint: u64) {
        self.knowledge.active_config_fingerprint = fingerprint;
    }

    /// Per-second background threads plus the MAPE-K loop gates, shared by
    /// the fused and staged decision paths: anomaly statistics and recovery
    /// monitoring always run; planning proceeds only on a due loop tick,
    /// outside the post-rescale grace period, with a serving job.
    fn loop_gate(&mut self, view: &SimView<'_>) -> bool {
        // Replay the background threads over every tick since the last
        // call. Per-tick operation makes this a single-tick range —
        // identical to calling them inline; with the event-driven harness
        // the skipped quiet-span ticks are reconstructed from the dense
        // TSDB (all skipped ticks are inside ready spans, so `ready` is
        // true for every tick but possibly the current one).
        for u in self.tracked_until..=view.now {
            let ready_u = if u == view.now { view.ready } else { true };
            // Re-anchor the lens at the replayed tick so staleness resolves
            // exactly as it did when `u` was "now"; under hardening a
            // degraded tick's diff is treated as no observation at all
            // (the anomaly normal and recovery monitor must not learn from
            // corrupted or stale samples).
            let raw = anomaly::diff_at(view.tsdb.at(u), u);
            let diff = if self.cfg.hardened && view.tsdb.degraded_at(u) {
                None
            } else {
                raw
            };
            // Straggler detection first (against the *pre-sample* normal),
            // then fold the sample into the difference statistics — unless
            // the window is quarantined: a gray-degraded deployment must
            // not redefine "normal" any more than it may write capacity.
            anomaly::straggler_tick(&mut self.knowledge, ready_u, diff);
            if let Some(d) = diff {
                if !self.knowledge.straggler_suspect() {
                    self.knowledge.anomaly.push_scalar(d);
                }
            }
            if let Some(mon) = &mut self.recovery_monitor {
                if mon.update_at(&mut self.knowledge, u, ready_u, diff) {
                    self.recovery_monitor = None;
                }
            }
        }
        self.tracked_until = view.now + 1;
        if view.now < self.next_loop {
            return false;
        }
        self.next_loop = view.now + self.cfg.loop_interval;
        if let Some(last) = self.knowledge.last_rescale {
            if view.now < last + self.cfg.grace_period {
                return false;
            }
        }
        // Quarantine capacity writes whose monitor window overlaps a
        // telemetry fault: the CPU/throughput moving averages look back
        // `cpu_window` seconds, so a fault anywhere in that span can poison
        // the capacity observation even if `now` itself reads clean.
        self.knowledge.set_telemetry_suspect(
            self.cfg.hardened
                && view
                    .tsdb
                    .degraded_over(view.now.saturating_sub(self.cfg.cpu_window), view.now + 1),
        );
        view.ready
    }

    /// Execute-phase bookkeeping shared by both paths: the pods will be
    /// recreated (placement and per-pod speed may change) — per-worker
    /// regression state starts fresh; the capacity ledgers persist.
    fn execute_bookkeeping(&mut self, now: crate::clock::Timestamp, scale_out: bool) {
        self.knowledge.reset_capacity_state();
        self.knowledge.last_rescale = Some(now);
        self.knowledge.rescale_count += 1;
        self.recovery_monitor = Some(RecoveryMonitor::start(now, scale_out));
    }

    /// One full MAPE-K iteration. Returns a desired parallelism if the plan
    /// phase decided to rescale.
    fn mape_iteration(&mut self, view: &SimView<'_>) -> Option<usize> {
        // Monitor (into the reusable buffer — allocation-free once warm).
        MonitorData::collect_into(view, &self.cfg, self.backend.meta(), &mut self.monitor_buf);
        let data = &self.monitor_buf;
        if data.workers.is_empty() {
            return None;
        }

        // Analyze: capacity models (artifact) + forecast (artifact + gate).
        let capacities = self.analyzer.update_capacity(
            &self.backend,
            &mut self.knowledge,
            data,
            self.cfg.cpu_target,
            self.cfg.skew_aware,
        );
        let forecast = forecasting::forecast(
            &self.backend,
            &mut self.knowledge,
            data,
            &self.cfg,
            view.now,
        );

        // Plan: Algorithm 1.
        let decision = plan::plan_scale_out(
            view.now,
            &capacities,
            data,
            &forecast,
            &self.knowledge,
            &self.cfg,
            view.max_replicas,
        );

        // Execute: only if it changes the parallelism.
        if decision.target != data.parallelism {
            if let Some(rt) = decision.predicted_recovery {
                self.knowledge
                    .predicted_recoveries
                    .push((view.now, rt));
            }
            Some(decision.target)
        } else {
            None
        }
    }
}

impl Autoscaler for Daedalus {
    fn name(&self) -> String {
        if self.cfg.hardened {
            "daedalus".to_string()
        } else {
            "daedalus-unguarded".to_string()
        }
    }

    fn decide(&mut self, view: &SimView<'_>) -> Option<usize> {
        if !self.loop_gate(view) {
            return None;
        }
        // Safe mode: while telemetry is degraded, hold the last plan and
        // arm the post-recovery cooldown. The background threads above
        // still ran; only planning is suspended.
        if self.cfg.hardened && view.tsdb.degraded() {
            self.plan_guard.hold(view.now);
            return None;
        }
        let mut decision = self.mape_iteration(view)?;
        if self.cfg.hardened {
            // First decisions after a hold are step-clamped: a plan built
            // on a freshly-recovered metric pipeline should not swing the
            // deployment in one move.
            decision = self.plan_guard.vet(view.now, view.parallelism, decision)?;
        }
        // Execute.
        let scale_out = decision > view.parallelism;
        self.execute_bookkeeping(view.now, scale_out);
        Some(decision)
    }

    fn decide_plan(&mut self, view: &SimView<'_>) -> Option<ScalePlan> {
        // Fused flat pool: the job-level MAPE-K loop as before.
        if view.stage_parallelism.is_empty() {
            return self.decide(view).map(ScalePlan::Uniform);
        }
        // Staged deployment: per-stage monitoring/knowledge/planning,
        // behind the same background threads and loop gates.
        if !self.loop_gate(view) {
            return None;
        }
        // Safe mode (same contract as the fused path): hold under degraded
        // telemetry, step-clamp the first post-recovery plan per stage.
        if self.cfg.hardened && view.tsdb.degraded() {
            self.plan_guard.hold(view.now);
            return None;
        }

        // Monitor: per-stage snapshots ride in the same reusable buffer.
        MonitorData::collect_into(view, &self.cfg, self.backend.meta(), &mut self.monitor_buf);
        if self.monitor_buf.stages.len() < view.stage_parallelism.len() {
            return None;
        }
        // Analyze: the forecast artifact is shared with the job-level
        // loop; per-stage capacity observations land in the knowledge
        // ledger inside the plan call below.
        let forecast = forecasting::forecast(
            &self.backend,
            &mut self.knowledge,
            &self.monitor_buf,
            &self.cfg,
            view.now,
        );
        // Plan: per-stage Algorithm 1.
        let mut decision = plan::plan_stage_scale_out(
            view.now,
            &self.monitor_buf,
            &forecast,
            &mut self.knowledge,
            &self.cfg,
            view.max_replicas,
            self.cfg.plan_checkpoint_interval,
        )?;
        if self.cfg.hardened {
            // Per-stage step clamp during the post-hold cooldown; a stage
            // whose clamped target collapses to its current parallelism
            // simply keeps it.
            for (target, &current) in decision
                .targets
                .iter_mut()
                .zip(view.stage_parallelism.iter())
            {
                *target = self
                    .plan_guard
                    .vet(view.now, current, *target)
                    .unwrap_or(current);
            }
        }
        if decision.targets == view.stage_parallelism {
            return None;
        }
        if let Some(rt) = decision.predicted_recovery {
            self.knowledge.predicted_recoveries.push((view.now, rt));
        }
        // Execute.
        let scale_out = decision.targets.iter().sum::<usize>()
            > view.stage_parallelism.iter().sum::<usize>();
        self.execute_bookkeeping(view.now, scale_out);
        Some(ScalePlan::PerStage(decision.targets))
    }

    /// Next loop tick. The per-second background threads are *not* a
    /// reason to wake up: `loop_gate` replays skipped ticks from the
    /// dense TSDB (see `tracked_until`), so intermediate `decide` calls
    /// carry no information the catch-up can't reconstruct.
    fn next_decision(&self, now: crate::clock::Timestamp) -> crate::clock::Timestamp {
        self.next_loop.max(now + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{EngineProfile, SimConfig, Simulation};
    use crate::jobs::JobProfile;
    use crate::workload::{ConstantWorkload, StepWorkload};

    fn run_with_daedalus(
        workload: Box<dyn crate::workload::Workload>,
        secs: u64,
    ) -> (Simulation, Daedalus) {
        let cfg = SimConfig {
            partitions: 36,
            seed: 42,
            rate_noise: 0.01,
            ..SimConfig::base(EngineProfile::flink(), JobProfile::wordcount(), workload)
        };
        let mut sim = Simulation::new(cfg);
        let mut d = Daedalus::new(DaedalusConfig::default(), ComputeBackend::native());
        for t in 0..secs {
            sim.step(t);
            if let Some(n) = d.decide(&sim.view()) {
                sim.request_rescale(n);
            }
        }
        (sim, d)
    }

    #[test]
    fn scales_in_when_overprovisioned() {
        // 4 workers ≈ 22k capacity for a 5k load → should shrink.
        let (sim, _) = run_with_daedalus(
            Box::new(ConstantWorkload {
                rate: 5_000.0,
                duration: 3_000,
            }),
            3_000,
        );
        assert!(
            sim.parallelism() <= 2,
            "still at {} workers",
            sim.parallelism()
        );
        // And it must still keep up.
        assert!(sim.total_backlog() < 20_000.0);
    }

    #[test]
    fn scales_out_when_underprovisioned() {
        // 4 workers ≈ 22k capacity, 40k load → must grow.
        let (sim, _) = run_with_daedalus(
            Box::new(ConstantWorkload {
                rate: 35_000.0,
                duration: 3_000,
            }),
            3_000,
        );
        assert!(sim.parallelism() >= 8, "only {} workers", sim.parallelism());
        // Lag must eventually drain.
        assert!(
            sim.total_backlog() < 100_000.0,
            "backlog {}",
            sim.total_backlog()
        );
    }

    #[test]
    fn reacts_to_step_increase() {
        let (sim, d) = run_with_daedalus(
            Box::new(StepWorkload {
                steps: vec![(0, 8_000.0), (1_500, 38_000.0)],
                duration: 4_000,
            }),
            4_000,
        );
        assert!(sim.parallelism() >= 9, "p = {}", sim.parallelism());
        assert!(d.knowledge().rescale_count >= 1);
        assert!(sim.total_backlog() < 100_000.0);
    }

    #[test]
    fn grace_period_limits_rescale_frequency() {
        let (sim, _) = run_with_daedalus(
            Box::new(ConstantWorkload {
                rate: 30_000.0,
                duration: 2_000,
            }),
            2_000,
        );
        // Consecutive rescales must be ≥ grace period apart.
        let log = &sim.rescale_log;
        for pair in log.windows(2) {
            assert!(
                pair[1].t - pair[0].t >= 180,
                "rescales too close: {:?}",
                pair
            );
        }
    }
}
