//! Plan phase — Algorithm 1 (§3.2): determine the scale-out.
//!
//! Faithful transcription of the paper's pseudocode:
//!
//! ```text
//! if time since last rescale < 600 s:
//!     if C_current > W_avg and C_current > TSF_max until next loop:
//!         return current parallelism
//! for i = 1 to MaxScaleout:
//!     if C_i > W_avg:
//!         RT_i ← predict_recovery_time(i)
//!         if RT_i > RT_target:            continue
//!         if C_i < TSF_max until RT_i:    continue
//!         if i == current parallelism:    return i
//!         if i < current and C_i < consumer lag: continue
//!         if C_i > TSF_max:               return i
//! return MaxScaleout
//! ```

use crate::autoscaler::guard;
use crate::clock::Timestamp;

use super::analyze::CapacityEstimates;
use super::forecasting::ForecastResult;
use super::knowledge::Knowledge;
use super::monitor::MonitorData;
use super::recovery::predict_recovery_time;
use super::DaedalusConfig;

/// Checkpoint interval assumed for replay-backlog worst case (§3.4). The
/// paper uses the job's configured 10 s interval.
pub const CHECKPOINT_INTERVAL: u64 = 10;

fn max_until(values: &[f64], secs: usize) -> f64 {
    values
        .iter()
        .take(secs.max(1))
        .copied()
        .fold(0.0, f64::max)
}

/// Outcome of the plan phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanDecision {
    /// Chosen parallelism (may equal the current one: "no rescale").
    pub target: usize,
    /// Predicted recovery time for the chosen scale-out, if one was
    /// computed (None when the early "long-lived" check short-circuits).
    pub predicted_recovery: Option<f64>,
}

/// Algorithm 1. Returns the chosen scale-out and its predicted recovery.
pub fn plan_scale_out(
    now: Timestamp,
    caps: &CapacityEstimates,
    data: &MonitorData,
    forecast: &ForecastResult,
    knowledge: &Knowledge,
    cfg: &DaedalusConfig,
    max_scaleout: usize,
) -> PlanDecision {
    let current = data.parallelism;
    let tsf = &forecast.values;
    let recent = &data.history[data.history.len().saturating_sub(60)..];

    // Long-lived decisions: right after a rescale, only interfere if the
    // current capacity is insufficient.
    if let Some(last) = knowledge.last_rescale {
        if now.saturating_sub(last) < cfg.long_lived_window {
            let until_next_loop = max_until(tsf, cfg.loop_interval as usize);
            let c_cur = caps.at(current);
            if c_cur > data.workload_avg && c_cur > until_next_loop {
                return PlanDecision { target: current, predicted_recovery: None };
            }
        }
    }

    let tsf_max_full = max_until(tsf, tsf.len());
    for i in 1..=max_scaleout {
        let c_i = caps.at(i);
        // Must cover the *observed* average workload (reactive guard).
        if c_i <= data.workload_avg {
            continue;
        }
        // Must recover within the target.
        let downtime = knowledge.anticipated_downtime(current, i);
        let rt = predict_recovery_time(c_i, recent, tsf, CHECKPOINT_INTERVAL, downtime);
        if cfg.use_recovery_constraint {
            if rt > cfg.recovery_target {
                continue;
            }
            // Must handle the workload *while* recovering.
            if c_i < max_until(tsf, rt.ceil().min(1e9) as usize) {
                continue;
            }
        }
        // Valid scale-out. Same as current → nothing to do.
        if i == current {
            return PlanDecision { target: i, predicted_recovery: Some(rt) };
        }
        // Scale-in protection: while the consumer lag exceeds the target
        // capacity the system is recovering/overloaded — wait (§3.2).
        if cfg.use_lag_guard && i < current && c_i < data.consumer_lag {
            continue;
        }
        // Long-lived: must also cover the full 15-minute forecast.
        if c_i > tsf_max_full {
            return PlanDecision { target: i, predicted_recovery: Some(rt) };
        }
    }
    PlanDecision { target: max_scaleout, predicted_recovery: None }
}

/// Outcome of the per-stage plan phase (staged deployments).
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlanDecision {
    /// Chosen per-stage replica counts (may equal the current vector).
    pub targets: Vec<usize>,
    /// Predicted recovery time for the chosen vector, if computed.
    pub predicted_recovery: Option<f64>,
}

/// Algorithm 1, per-operator: every stage gets the smallest replica count
/// whose *observed-over-predicted* capacity covers that stage's share of
/// the observed and forecast workload; the recovery-time constraint is then
/// enforced at the job level by growing the bottleneck stage, and the
/// consumer-lag guard blocks net scale-ins while the pipeline is behind.
/// Also folds this iteration's per-stage capacity observations into the
/// knowledge ledger (the monitor/knowledge half of the staged loop).
pub fn plan_stage_scale_out(
    _now: Timestamp,
    data: &MonitorData,
    forecast: &ForecastResult,
    knowledge: &mut Knowledge,
    cfg: &DaedalusConfig,
    max_scaleout: usize,
) -> Option<StagePlanDecision> {
    let n_stages = data.stages.len();
    if n_stages == 0 || data.stage_parallelism.len() != n_stages {
        return None;
    }
    let tsf = &forecast.values;
    let recent = &data.history[data.history.len().saturating_sub(60)..];

    // Observe: per-replica capacity from exact per-stage busy fractions,
    // folded into the (stage, n) ledger.
    let mut per_replica = Vec::with_capacity(n_stages);
    for snap in &data.stages {
        let n_s = data.stage_parallelism[snap.stage].max(1);
        let busy = snap.busy.clamp(0.05, 1.0);
        // Shared finite/positive gate (guard module): a corrupted
        // throughput sample (NaN/∞) or an idle stage must read as "no
        // observation", not as a capacity.
        let cap_rep = guard::finite_pos((snap.throughput / n_s as f64) / busy)?;
        // Ledger quarantine (same rule as the fused path): straggler- or
        // telemetry-suspect windows plan from this fresh estimate but
        // never persist it as the healthy capacity of `(stage, n_s)`.
        if !knowledge.capacity_quarantined() {
            knowledge
                .stage_capacity
                .insert((snap.stage, n_s), cap_rep * n_s as f64);
        }
        per_replica.push(cap_rep);
    }
    // Cumulative observed selectivity: stage s's input per source tuple.
    let mut cumsel = vec![1.0; n_stages];
    for s in 1..n_stages {
        let up = &data.stages[s - 1];
        let ratio = if up.throughput > 1e-9 {
            (data.stages[s].throughput / up.throughput).clamp(0.01, 20.0)
        } else {
            1.0
        };
        cumsel[s] = cumsel[s - 1] * ratio;
    }
    let cap_at = |knowledge: &Knowledge, s: usize, n: usize| -> f64 {
        match knowledge.stage_capacity.get(&(s, n)) {
            Some(c) => *c,
            None => per_replica[s] * n as f64,
        }
    };

    // Plan: smallest per-stage replica count covering the observed average
    // and the forecast horizon, in this stage's input units.
    let tsf_max_full = max_until(tsf, tsf.len());
    let demand_source = data.workload_avg.max(tsf_max_full);
    let mut targets = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        let demand_s = demand_source * cumsel[s];
        let mut n = max_scaleout;
        for cand in 1..=max_scaleout {
            if cap_at(knowledge, s, cand) > demand_s {
                n = cand;
                break;
            }
        }
        targets.push(n);
    }

    // Execute constraint: the pipeline must recover within the target. The
    // job's source-rate capacity is the tightest stage's capacity mapped
    // back to source units; grow the bottleneck stage until the predicted
    // recovery fits (or nothing can grow).
    let current = &data.stage_parallelism;
    let pipeline_cap = |knowledge: &Knowledge, targets: &[usize]| -> (f64, usize) {
        let mut cap = f64::INFINITY;
        let mut argmin = 0;
        for s in 0..n_stages {
            let c = cap_at(knowledge, s, targets[s]) / cumsel[s].max(1e-9);
            if c < cap {
                cap = c;
                argmin = s;
            }
        }
        (cap, argmin)
    };
    let cur_total: usize = current.iter().sum();
    let mut predicted = None;
    if cfg.use_recovery_constraint {
        for _ in 0..(n_stages * max_scaleout) {
            let (c_src, bottleneck) = pipeline_cap(knowledge, &targets);
            let tgt_total: usize = targets.iter().sum();
            let downtime = knowledge.anticipated_downtime(cur_total, tgt_total);
            let rt = predict_recovery_time(c_src, recent, tsf, CHECKPOINT_INTERVAL, downtime);
            if rt <= cfg.recovery_target || targets[bottleneck] >= max_scaleout {
                predicted = Some(rt);
                break;
            }
            targets[bottleneck] += 1;
        }
    } else {
        let (c_src, _) = pipeline_cap(knowledge, &targets);
        let tgt_total: usize = targets.iter().sum();
        let downtime = knowledge.anticipated_downtime(cur_total, tgt_total);
        predicted = Some(predict_recovery_time(
            c_src,
            recent,
            tsf,
            CHECKPOINT_INTERVAL,
            downtime,
        ));
    }

    // Consumer-lag scale-in protection (§3.2), at the job level: while the
    // pipeline is behind by more than its source capacity, hold.
    let tgt_total: usize = targets.iter().sum();
    if cfg.use_lag_guard && tgt_total < cur_total {
        let (c_src, _) = pipeline_cap(knowledge, &targets);
        if c_src < data.consumer_lag {
            return Some(StagePlanDecision {
                targets: current.clone(),
                predicted_recovery: None,
            });
        }
    }
    Some(StagePlanDecision {
        targets,
        predicted_recovery: predicted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn caps_linear(per_worker: f64, parallelism: usize) -> CapacityEstimates {
        CapacityEstimates {
            per_worker: vec![per_worker; parallelism],
            current: per_worker * parallelism as f64,
            parallelism,
            avg_per_worker: per_worker,
            seen: HashMap::new(),
        }
    }

    fn data(avg: f64, lag: f64, parallelism: usize) -> MonitorData {
        MonitorData {
            now: 1_000,
            history: vec![avg; 1800],
            workload_avg: avg,
            workload_max: avg * 1.05,
            consumer_lag: lag,
            parallelism,
            ..MonitorData::empty()
        }
    }

    fn fc(vals: Vec<f64>) -> ForecastResult {
        ForecastResult {
            values: vals,
            from_model: true,
            prev_wape: None,
        }
    }

    fn knowledge() -> Knowledge {
        Knowledge::new(&crate::runtime::ArtifactMeta::default(), 30.0, 15.0)
    }

    #[test]
    fn picks_minimum_sufficient_scaleout() {
        // 5k per worker, 12k steady workload → needs ≥ 3 workers... but
        // recovery headroom pushes it to the smallest i whose capacity
        // covers workload AND recovers in 600 s. i=3 gives 15k vs 12k → 3k
        // spare; backlog ≈ 12k·10 + 12k·30 = 480k → 160 s. Valid.
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 3);
        assert!(decision.predicted_recovery.unwrap() < 600.0);
    }

    #[test]
    fn recovery_target_forces_larger_scaleout() {
        // Same but a tight 60 s recovery target: i=3 takes ~160 s → skip;
        // i=4 → 20k cap, 8k spare → backlog 480k/8k = 60 s + fits.
        let mut cfg = DaedalusConfig::default();
        cfg.recovery_target = 100.0;
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &cfg,
            18,
        );
        assert!(decision.target > 3, "decision {decision:?}");
        assert!(decision.target <= 5);
        assert!(decision.predicted_recovery.unwrap() <= 100.0);
    }

    #[test]
    fn consumer_lag_blocks_scale_in() {
        // Over-provisioned (8 × 5k for 12k load) but a huge lag: the
        // scale-in candidates (3..7) are all below the lag → wait at 8.
        let d = data(12_000.0, 10_000_000.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 8);
    }

    #[test]
    fn rising_forecast_provisions_ahead() {
        // Steady 12k now but forecast ramps to 40k → needs ≥ 9 workers
        // (45k) to cover the full forecast.
        let d = data(12_000.0, 0.0, 3);
        let rising: Vec<f64> = (0..900).map(|s| 12_000.0 + 28_000.0 * s as f64 / 900.0).collect();
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 3),
            &d,
            &fc(rising),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        // Forecast max ≈ 40k → needs ≥ 8 workers (40k capacity).
        assert!(decision.target >= 8, "decision {decision:?}");
    }

    #[test]
    fn recent_rescale_short_circuits_when_capacity_sufficient() {
        let mut k = knowledge();
        k.last_rescale = Some(900); // 100 s ago < 600 s window
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &k,
            &DaedalusConfig::default(),
            18,
        );
        // Would otherwise scale in to 3; the long-lived check holds at 8.
        assert_eq!(decision.target, 8);
    }

    #[test]
    fn recent_rescale_does_not_block_needed_scale_out() {
        let mut k = knowledge();
        k.last_rescale = Some(900);
        // Capacity 15k < workload 20k → the short-circuit must NOT trigger.
        let d = data(20_000.0, 0.0, 3);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 3),
            &d,
            &fc(vec![20_000.0; 900]),
            &k,
            &DaedalusConfig::default(),
            18,
        );
        assert!(decision.target > 3, "decision {decision:?}");
    }

    fn staged_data(avg: f64, lag: f64) -> MonitorData {
        use crate::metrics::query::StageSnapshot;
        // Three stages at 2 replicas each; the middle stage amplifies ×3.
        // Per-replica true capacities: 20k / 6.25k / 15k.
        MonitorData {
            now: 1_000,
            stages: vec![
                StageSnapshot {
                    stage: 0,
                    parallelism: 2,
                    busy: 0.25,
                    throughput: avg,
                    queue: 0.0,
                },
                StageSnapshot {
                    stage: 1,
                    parallelism: 2,
                    busy: 0.8,
                    throughput: avg,
                    queue: 0.0,
                },
                StageSnapshot {
                    stage: 2,
                    parallelism: 2,
                    busy: 1.0,
                    throughput: avg * 3.0,
                    queue: 0.0,
                },
            ],
            stage_parallelism: vec![2, 2, 2],
            history: vec![avg; 1800],
            workload_avg: avg,
            workload_max: avg * 1.05,
            consumer_lag: lag,
            parallelism: 2,
            ..MonitorData::empty()
        }
    }

    #[test]
    fn stage_plan_targets_each_operator_minimally() {
        let mut k = knowledge();
        let d = staged_data(10_000.0, 0.0);
        let decision = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
        )
        .expect("plan");
        // Stage 0: 20k/replica for 10k → 1. Stage 1: 6.25k/replica for
        // 10k → 2. Stage 2: 15k/replica for 30k (×3) → 3.
        assert_eq!(decision.targets, vec![1, 2, 3]);
        // Ledger recorded the observed (stage, n) capacities.
        crate::assert_close!(k.stage_capacity[&(0, 2)], 40_000.0, rtol = 1e-9);
        crate::assert_close!(k.stage_capacity[&(1, 2)], 12_500.0, rtol = 1e-9);
    }

    #[test]
    fn stage_plan_lag_guard_blocks_net_scale_in() {
        let mut k = knowledge();
        // Lightly loaded pipeline whose minimal vector [1, 1, 2] is a net
        // scale-in from [2, 2, 2] — but a huge consumer lag must hold it.
        let mut d = staged_data(2_000.0, 50_000_000.0);
        d.stages[1].busy = 0.2; // per-replica 5k → stage 1 needs 1
        d.stages[2].busy = 0.75; // per-replica 4k for 6k demand → needs 2
        let held = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![2_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
        )
        .expect("plan");
        assert_eq!(held.targets, vec![2, 2, 2], "lag guard must hold the current vector");
        // Without the lag, the same pipeline shrinks.
        let mut k2 = knowledge();
        let mut d2 = staged_data(2_000.0, 0.0);
        d2.stages[1].busy = 0.2;
        d2.stages[2].busy = 0.75;
        let shrunk = plan_stage_scale_out(
            1_000,
            &d2,
            &fc(vec![2_000.0; 900]),
            &mut k2,
            &DaedalusConfig::default(),
            12,
        )
        .expect("plan");
        assert!(
            shrunk.targets.iter().sum::<usize>() < 6,
            "expected a net scale-in, got {:?}",
            shrunk.targets
        );
    }

    #[test]
    fn stage_plan_recovery_constraint_grows_bottleneck() {
        let mut k = knowledge();
        let mut cfg = DaedalusConfig::default();
        cfg.recovery_target = 60.0;
        let d = staged_data(10_000.0, 0.0);
        let relaxed = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
        )
        .unwrap();
        let tight = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &cfg,
            12,
        )
        .unwrap();
        assert!(
            tight.targets.iter().sum::<usize>() > relaxed.targets.iter().sum::<usize>(),
            "tight {:?} vs relaxed {:?}",
            tight.targets,
            relaxed.targets
        );
        assert!(tight.predicted_recovery.unwrap() <= 60.0 || tight.targets.contains(&12));
    }

    #[test]
    fn impossible_demands_return_max_scaleout() {
        // Workload beyond any capacity → MaxScaleout (the algorithm's
        // final fallback line).
        let d = data(500_000.0, 0.0, 4);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 4),
            &d,
            &fc(vec![500_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 18);
    }
}
