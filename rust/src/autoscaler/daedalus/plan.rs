//! Plan phase — Algorithm 1 (§3.2): determine the scale-out.
//!
//! Faithful transcription of the paper's pseudocode:
//!
//! ```text
//! if time since last rescale < 600 s:
//!     if C_current > W_avg and C_current > TSF_max until next loop:
//!         return current parallelism
//! for i = 1 to MaxScaleout:
//!     if C_i > W_avg:
//!         RT_i ← predict_recovery_time(i)
//!         if RT_i > RT_target:            continue
//!         if C_i < TSF_max until RT_i:    continue
//!         if i == current parallelism:    return i
//!         if i < current and C_i < consumer lag: continue
//!         if C_i > TSF_max:               return i
//! return MaxScaleout
//! ```

use crate::autoscaler::guard;
use crate::clock::Timestamp;

use super::analyze::CapacityEstimates;
use super::forecasting::ForecastResult;
use super::knowledge::Knowledge;
use super::monitor::MonitorData;
use super::recovery::predict_recovery_time;
use super::DaedalusConfig;

/// Checkpoint interval assumed for replay-backlog worst case (§3.4). The
/// paper uses the job's configured 10 s interval; scale-out-only Daedalus
/// plans with this constant, while config-aware planners (demeter) pass
/// their *actual* interval into [`plan_stage_scale_out`] — a shorter
/// interval genuinely shrinks the replay backlog, so the recovery
/// constraint binds later and over-provisions less.
pub const CHECKPOINT_INTERVAL: u64 = 10;

fn max_until(values: &[f64], secs: usize) -> f64 {
    values
        .iter()
        .take(secs.max(1))
        .copied()
        .fold(0.0, f64::max)
}

/// Outcome of the plan phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanDecision {
    /// Chosen parallelism (may equal the current one: "no rescale").
    pub target: usize,
    /// Predicted recovery time for the chosen scale-out, if one was
    /// computed (None when the early "long-lived" check short-circuits).
    pub predicted_recovery: Option<f64>,
}

/// Algorithm 1. Returns the chosen scale-out and its predicted recovery.
pub fn plan_scale_out(
    now: Timestamp,
    caps: &CapacityEstimates,
    data: &MonitorData,
    forecast: &ForecastResult,
    knowledge: &Knowledge,
    cfg: &DaedalusConfig,
    max_scaleout: usize,
) -> PlanDecision {
    let current = data.parallelism;
    let tsf = &forecast.values;
    let recent = &data.history[data.history.len().saturating_sub(60)..];

    // Long-lived decisions: right after a rescale, only interfere if the
    // current capacity is insufficient.
    if let Some(last) = knowledge.last_rescale {
        if now.saturating_sub(last) < cfg.long_lived_window {
            let until_next_loop = max_until(tsf, cfg.loop_interval as usize);
            let c_cur = caps.at(current);
            if c_cur > data.workload_avg && c_cur > until_next_loop {
                return PlanDecision { target: current, predicted_recovery: None };
            }
        }
    }

    let tsf_max_full = max_until(tsf, tsf.len());
    for i in 1..=max_scaleout {
        let c_i = caps.at(i);
        // Must cover the *observed* average workload (reactive guard).
        if c_i <= data.workload_avg {
            continue;
        }
        // Must recover within the target.
        let downtime = knowledge.anticipated_downtime(current, i);
        let rt = predict_recovery_time(c_i, recent, tsf, CHECKPOINT_INTERVAL, downtime);
        if cfg.use_recovery_constraint {
            if rt > cfg.recovery_target {
                continue;
            }
            // Must handle the workload *while* recovering.
            if c_i < max_until(tsf, rt.ceil().min(1e9) as usize) {
                continue;
            }
        }
        // Valid scale-out. Same as current → nothing to do.
        if i == current {
            return PlanDecision { target: i, predicted_recovery: Some(rt) };
        }
        // Scale-in protection: while the consumer lag exceeds the target
        // capacity the system is recovering/overloaded — wait (§3.2).
        if cfg.use_lag_guard && i < current && c_i < data.consumer_lag {
            continue;
        }
        // Long-lived: must also cover the full 15-minute forecast.
        if c_i > tsf_max_full {
            return PlanDecision { target: i, predicted_recovery: Some(rt) };
        }
    }
    PlanDecision { target: max_scaleout, predicted_recovery: None }
}

/// Outcome of the per-stage plan phase (staged deployments).
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlanDecision {
    /// Chosen per-stage replica counts (may equal the current vector).
    pub targets: Vec<usize>,
    /// Predicted recovery time for the chosen vector, if computed.
    pub predicted_recovery: Option<f64>,
}

/// Algorithm 1, per-operator: every stage gets the smallest replica count
/// whose *observed-over-predicted* capacity covers that stage's share of
/// the observed and forecast workload; the recovery-time constraint is then
/// enforced at the job level by growing the bottleneck stage, and the
/// consumer-lag guard blocks net scale-ins while the pipeline is behind.
/// Also folds this iteration's per-stage capacity observations into the
/// knowledge ledger (the monitor/knowledge half of the staged loop).
///
/// `checkpoint_interval` is the interval the replay-backlog worst case is
/// computed with: pass [`CHECKPOINT_INTERVAL`] for the paper's fixed-config
/// Daedalus, or the active [`crate::dsp::RuntimeConfig`] interval for
/// config-aware planners.
pub fn plan_stage_scale_out(
    _now: Timestamp,
    data: &MonitorData,
    forecast: &ForecastResult,
    knowledge: &mut Knowledge,
    cfg: &DaedalusConfig,
    max_scaleout: usize,
    checkpoint_interval: u64,
) -> Option<StagePlanDecision> {
    let n_stages = data.stages.len();
    if n_stages == 0 || data.stage_parallelism.len() != n_stages {
        return None;
    }
    let tsf = &forecast.values;
    let recent = &data.history[data.history.len().saturating_sub(60)..];

    // Observe: per-replica capacity from exact per-stage busy fractions,
    // folded into the (stage, n) ledger.
    let mut per_replica = Vec::with_capacity(n_stages);
    for snap in &data.stages {
        let n_s = data.stage_parallelism[snap.stage].max(1);
        let busy = snap.busy.clamp(0.05, 1.0);
        // Shared finite/positive gate (guard module): a corrupted
        // throughput sample (NaN/∞) or an idle stage must read as "no
        // observation", not as a capacity.
        let cap_rep = guard::finite_pos((snap.throughput / n_s as f64) / busy)?;
        // Ledger quarantine (same rule as the fused path): straggler- or
        // telemetry-suspect windows plan from this fresh estimate but
        // never persist it as the healthy capacity of `(stage, n_s)`.
        if !knowledge.capacity_quarantined() {
            knowledge
                .stage_capacity
                .insert((snap.stage, n_s), cap_rep * n_s as f64);
        }
        // Config-keyed twin ledger (ISSUE 10): same observation, same
        // quarantine gate (inside the method), keyed additionally by the
        // active config fingerprint. Written for every planner; read only
        // when `use_config_ledger` is set.
        knowledge.observe_config_capacity(snap.stage, n_s, cap_rep * n_s as f64);
        per_replica.push(cap_rep);
    }
    // Cumulative observed selectivity: stage s's input per source tuple.
    let mut cumsel = vec![1.0; n_stages];
    for s in 1..n_stages {
        let up = &data.stages[s - 1];
        let ratio = if up.throughput > 1e-9 {
            (data.stages[s].throughput / up.throughput).clamp(0.01, 20.0)
        } else {
            1.0
        };
        cumsel[s] = cumsel[s - 1] * ratio;
    }
    let cap_at = |knowledge: &Knowledge, s: usize, n: usize| -> f64 {
        // Config-aware planners prefer a capacity observed under the
        // *active* runtime config over the config-agnostic ledger: the
        // same `(stage, n)` can serve measurably different throughput
        // under different queue bounds / checkpoint intervals.
        if cfg.use_config_ledger {
            if let Some(c) = knowledge.config_capacity(s, n) {
                return c;
            }
        }
        match knowledge.stage_capacity.get(&(s, n)) {
            Some(c) => *c,
            None => per_replica[s] * n as f64,
        }
    };

    // Plan: smallest per-stage replica count covering the observed average
    // and the forecast horizon, in this stage's input units.
    let tsf_max_full = max_until(tsf, tsf.len());
    let demand_source = data.workload_avg.max(tsf_max_full);
    let mut targets = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        let demand_s = demand_source * cumsel[s];
        let mut n = max_scaleout;
        for cand in 1..=max_scaleout {
            if cap_at(knowledge, s, cand) > demand_s {
                n = cand;
                break;
            }
        }
        targets.push(n);
    }

    // Execute constraint: the pipeline must recover within the target. The
    // job's source-rate capacity is the tightest stage's capacity mapped
    // back to source units; grow the bottleneck stage until the predicted
    // recovery fits (or nothing can grow).
    let current = &data.stage_parallelism;
    let pipeline_cap = |knowledge: &Knowledge, targets: &[usize]| -> (f64, usize) {
        let mut cap = f64::INFINITY;
        let mut argmin = 0;
        for s in 0..n_stages {
            let c = cap_at(knowledge, s, targets[s]) / cumsel[s].max(1e-9);
            if c < cap {
                cap = c;
                argmin = s;
            }
        }
        (cap, argmin)
    };
    let cur_total: usize = current.iter().sum();
    let mut predicted = None;
    if cfg.use_recovery_constraint {
        for _ in 0..(n_stages * max_scaleout) {
            let (c_src, bottleneck) = pipeline_cap(knowledge, &targets);
            let tgt_total: usize = targets.iter().sum();
            let downtime = knowledge.anticipated_downtime(cur_total, tgt_total);
            let rt = predict_recovery_time(c_src, recent, tsf, checkpoint_interval, downtime);
            if rt <= cfg.recovery_target || targets[bottleneck] >= max_scaleout {
                predicted = Some(rt);
                break;
            }
            targets[bottleneck] += 1;
        }
    } else {
        let (c_src, _) = pipeline_cap(knowledge, &targets);
        let tgt_total: usize = targets.iter().sum();
        let downtime = knowledge.anticipated_downtime(cur_total, tgt_total);
        predicted = Some(predict_recovery_time(
            c_src,
            recent,
            tsf,
            checkpoint_interval,
            downtime,
        ));
    }

    // Consumer-lag scale-in protection (§3.2), at the job level: while the
    // pipeline is behind by more than its source capacity, hold.
    let tgt_total: usize = targets.iter().sum();
    if cfg.use_lag_guard && tgt_total < cur_total {
        let (c_src, _) = pipeline_cap(knowledge, &targets);
        if c_src < data.consumer_lag {
            return Some(StagePlanDecision {
                targets: current.clone(),
                predicted_recovery: None,
            });
        }
    }
    Some(StagePlanDecision {
        targets,
        predicted_recovery: predicted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn caps_linear(per_worker: f64, parallelism: usize) -> CapacityEstimates {
        CapacityEstimates {
            per_worker: vec![per_worker; parallelism],
            current: per_worker * parallelism as f64,
            parallelism,
            avg_per_worker: per_worker,
            seen: HashMap::new(),
        }
    }

    fn data(avg: f64, lag: f64, parallelism: usize) -> MonitorData {
        MonitorData {
            now: 1_000,
            history: vec![avg; 1800],
            workload_avg: avg,
            workload_max: avg * 1.05,
            consumer_lag: lag,
            parallelism,
            ..MonitorData::empty()
        }
    }

    fn fc(vals: Vec<f64>) -> ForecastResult {
        ForecastResult {
            values: vals,
            from_model: true,
            prev_wape: None,
        }
    }

    fn knowledge() -> Knowledge {
        Knowledge::new(&crate::runtime::ArtifactMeta::default(), 30.0, 15.0)
    }

    #[test]
    fn picks_minimum_sufficient_scaleout() {
        // 5k per worker, 12k steady workload → needs ≥ 3 workers... but
        // recovery headroom pushes it to the smallest i whose capacity
        // covers workload AND recovers in 600 s. i=3 gives 15k vs 12k → 3k
        // spare; backlog ≈ 12k·10 + 12k·30 = 480k → 160 s. Valid.
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 3);
        assert!(decision.predicted_recovery.unwrap() < 600.0);
    }

    #[test]
    fn recovery_target_forces_larger_scaleout() {
        // Same but a tight 60 s recovery target: i=3 takes ~160 s → skip;
        // i=4 → 20k cap, 8k spare → backlog 480k/8k = 60 s + fits.
        let mut cfg = DaedalusConfig::default();
        cfg.recovery_target = 100.0;
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &cfg,
            18,
        );
        assert!(decision.target > 3, "decision {decision:?}");
        assert!(decision.target <= 5);
        assert!(decision.predicted_recovery.unwrap() <= 100.0);
    }

    #[test]
    fn consumer_lag_blocks_scale_in() {
        // Over-provisioned (8 × 5k for 12k load) but a huge lag: the
        // scale-in candidates (3..7) are all below the lag → wait at 8.
        let d = data(12_000.0, 10_000_000.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 8);
    }

    #[test]
    fn rising_forecast_provisions_ahead() {
        // Steady 12k now but forecast ramps to 40k → needs ≥ 9 workers
        // (45k) to cover the full forecast.
        let d = data(12_000.0, 0.0, 3);
        let rising: Vec<f64> = (0..900).map(|s| 12_000.0 + 28_000.0 * s as f64 / 900.0).collect();
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 3),
            &d,
            &fc(rising),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        // Forecast max ≈ 40k → needs ≥ 8 workers (40k capacity).
        assert!(decision.target >= 8, "decision {decision:?}");
    }

    #[test]
    fn recent_rescale_short_circuits_when_capacity_sufficient() {
        let mut k = knowledge();
        k.last_rescale = Some(900); // 100 s ago < 600 s window
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &k,
            &DaedalusConfig::default(),
            18,
        );
        // Would otherwise scale in to 3; the long-lived check holds at 8.
        assert_eq!(decision.target, 8);
    }

    #[test]
    fn recent_rescale_does_not_block_needed_scale_out() {
        let mut k = knowledge();
        k.last_rescale = Some(900);
        // Capacity 15k < workload 20k → the short-circuit must NOT trigger.
        let d = data(20_000.0, 0.0, 3);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 3),
            &d,
            &fc(vec![20_000.0; 900]),
            &k,
            &DaedalusConfig::default(),
            18,
        );
        assert!(decision.target > 3, "decision {decision:?}");
    }

    fn staged_data(avg: f64, lag: f64) -> MonitorData {
        use crate::metrics::query::StageSnapshot;
        // Three stages at 2 replicas each; the middle stage amplifies ×3.
        // Per-replica true capacities: 20k / 6.25k / 15k.
        MonitorData {
            now: 1_000,
            stages: vec![
                StageSnapshot {
                    stage: 0,
                    parallelism: 2,
                    busy: 0.25,
                    throughput: avg,
                    queue: 0.0,
                },
                StageSnapshot {
                    stage: 1,
                    parallelism: 2,
                    busy: 0.8,
                    throughput: avg,
                    queue: 0.0,
                },
                StageSnapshot {
                    stage: 2,
                    parallelism: 2,
                    busy: 1.0,
                    throughput: avg * 3.0,
                    queue: 0.0,
                },
            ],
            stage_parallelism: vec![2, 2, 2],
            history: vec![avg; 1800],
            workload_avg: avg,
            workload_max: avg * 1.05,
            consumer_lag: lag,
            parallelism: 2,
            ..MonitorData::empty()
        }
    }

    #[test]
    fn stage_plan_targets_each_operator_minimally() {
        let mut k = knowledge();
        let d = staged_data(10_000.0, 0.0);
        let decision = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        // Stage 0: 20k/replica for 10k → 1. Stage 1: 6.25k/replica for
        // 10k → 2. Stage 2: 15k/replica for 30k (×3) → 3.
        assert_eq!(decision.targets, vec![1, 2, 3]);
        // Ledger recorded the observed (stage, n) capacities.
        crate::assert_close!(k.stage_capacity[&(0, 2)], 40_000.0, rtol = 1e-9);
        crate::assert_close!(k.stage_capacity[&(1, 2)], 12_500.0, rtol = 1e-9);
    }

    #[test]
    fn stage_plan_lag_guard_blocks_net_scale_in() {
        let mut k = knowledge();
        // Lightly loaded pipeline whose minimal vector [1, 1, 2] is a net
        // scale-in from [2, 2, 2] — but a huge consumer lag must hold it.
        let mut d = staged_data(2_000.0, 50_000_000.0);
        d.stages[1].busy = 0.2; // per-replica 5k → stage 1 needs 1
        d.stages[2].busy = 0.75; // per-replica 4k for 6k demand → needs 2
        let held = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![2_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        assert_eq!(held.targets, vec![2, 2, 2], "lag guard must hold the current vector");
        // Without the lag, the same pipeline shrinks.
        let mut k2 = knowledge();
        let mut d2 = staged_data(2_000.0, 0.0);
        d2.stages[1].busy = 0.2;
        d2.stages[2].busy = 0.75;
        let shrunk = plan_stage_scale_out(
            1_000,
            &d2,
            &fc(vec![2_000.0; 900]),
            &mut k2,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        assert!(
            shrunk.targets.iter().sum::<usize>() < 6,
            "expected a net scale-in, got {:?}",
            shrunk.targets
        );
    }

    #[test]
    fn stage_plan_recovery_constraint_grows_bottleneck() {
        let mut k = knowledge();
        let mut cfg = DaedalusConfig::default();
        cfg.recovery_target = 60.0;
        let d = staged_data(10_000.0, 0.0);
        let relaxed = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .unwrap();
        let tight = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &cfg,
            12,
            CHECKPOINT_INTERVAL,
        )
        .unwrap();
        assert!(
            tight.targets.iter().sum::<usize>() > relaxed.targets.iter().sum::<usize>(),
            "tight {:?} vs relaxed {:?}",
            tight.targets,
            relaxed.targets
        );
        assert!(tight.predicted_recovery.unwrap() <= 60.0 || tight.targets.contains(&12));
    }

    #[test]
    fn stage_plan_refuses_empty_or_mismatched_stage_data() {
        // No stage snapshots at all → no plan (the staged loop has nothing
        // to observe); likewise a parallelism vector that doesn't line up.
        let mut k = knowledge();
        let mut d = staged_data(10_000.0, 0.0);
        d.stages.clear();
        d.stage_parallelism.clear();
        assert!(plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .is_none());
        let mut d2 = staged_data(10_000.0, 0.0);
        d2.stage_parallelism.pop();
        assert!(plan_stage_scale_out(
            1_000,
            &d2,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .is_none());
        assert!(k.stage_capacity.is_empty(), "refused plans must not write the ledger");
    }

    #[test]
    fn stage_plan_with_empty_ledger_plans_from_fresh_estimates() {
        // An empty (stage, n) ledger — first loop of a run — must still
        // produce the minimal vector, purely from the in-loop per-replica
        // estimates, and must seed the ledger as a side effect.
        let mut k = knowledge();
        assert!(k.stage_capacity.is_empty());
        let d = staged_data(10_000.0, 0.0);
        let decision = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        assert_eq!(decision.targets, vec![1, 2, 3]);
        assert_eq!(k.stage_capacity.len(), 3);
        assert_eq!(k.stage_config_capacity.len(), 3);
    }

    #[test]
    fn stage_plan_with_all_cells_quarantined_never_persists() {
        // Every (stage, n) observation this window is suspect: planning
        // still works from the fresh estimates, but both ledgers stay
        // empty — a degraded window must never be remembered as healthy
        // capacity under any config.
        let mut k = knowledge();
        k.set_telemetry_suspect(true);
        let d = staged_data(10_000.0, 0.0);
        let decision = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        assert_eq!(decision.targets, vec![1, 2, 3], "plan still uses fresh estimates");
        assert!(k.stage_capacity.is_empty());
        assert!(k.stage_config_capacity.is_empty());
        assert_eq!(k.telemetry_quarantined_windows, 1);
    }

    #[test]
    fn shorter_checkpoint_interval_relaxes_the_recovery_constraint() {
        // The demeter economics: with a binding recovery target, a shorter
        // checkpoint interval means less worst-case replay, so the
        // constraint stops growing the bottleneck earlier — never more
        // replicas, and strictly fewer when the constraint binds.
        let mut cfg = DaedalusConfig::default();
        cfg.recovery_target = 45.0;
        let d = staged_data(10_000.0, 0.0);
        let mut k_long = knowledge();
        let long = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k_long,
            &cfg,
            12,
            30,
        )
        .expect("plan");
        let mut k_short = knowledge();
        let short = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut k_short,
            &cfg,
            12,
            5,
        )
        .expect("plan");
        let (n_long, n_short): (usize, usize) =
            (long.targets.iter().sum(), short.targets.iter().sum());
        // 30 s of replay vs 5 s of replay at a 45 s target: the binding
        // constraint needs ~40k/s of spare capacity vs ~23k/s, several
        // replicas apart.
        assert!(n_short < n_long, "short {short:?} vs long {long:?}");
        assert!(short.predicted_recovery.unwrap() <= cfg.recovery_target);
        assert!(long.predicted_recovery.unwrap() <= cfg.recovery_target);
    }

    #[test]
    fn config_ledger_overrides_capacity_when_enabled() {
        // With `use_config_ledger`, a capacity observed under the active
        // fingerprint wins over the config-agnostic ledger; without it the
        // same knowledge plans exactly as before.
        let mut base = knowledge();
        let d = staged_data(10_000.0, 0.0);
        // Seed both ledgers from one clean window.
        plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut base,
            &DaedalusConfig::default(),
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        // Under a *different* fingerprint, stage 1's capacity at n=2 is
        // remembered as much higher — enough to cover the demand with 2.
        base.active_config_fingerprint = 77;
        base.stage_config_capacity
            .insert((2, 2, 77), {
                let mut w = crate::stats::Welford::new();
                w.push_scalar(40_000.0);
                w
            });
        let mut cfg = DaedalusConfig::default();
        cfg.use_config_ledger = true;
        let aware = plan_stage_scale_out(
            1_000,
            &d,
            &fc(vec![10_000.0; 900]),
            &mut base,
            &cfg,
            12,
            CHECKPOINT_INTERVAL,
        )
        .expect("plan");
        // Stage 2 (demand 30k) is covered by the remembered 40k at n=2.
        assert_eq!(aware.targets[2], 2, "config cell must override: {aware:?}");
    }

    #[test]
    fn impossible_demands_return_max_scaleout() {
        // Workload beyond any capacity → MaxScaleout (the algorithm's
        // final fallback line).
        let d = data(500_000.0, 0.0, 4);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 4),
            &d,
            &fc(vec![500_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 18);
    }
}
