//! Plan phase — Algorithm 1 (§3.2): determine the scale-out.
//!
//! Faithful transcription of the paper's pseudocode:
//!
//! ```text
//! if time since last rescale < 600 s:
//!     if C_current > W_avg and C_current > TSF_max until next loop:
//!         return current parallelism
//! for i = 1 to MaxScaleout:
//!     if C_i > W_avg:
//!         RT_i ← predict_recovery_time(i)
//!         if RT_i > RT_target:            continue
//!         if C_i < TSF_max until RT_i:    continue
//!         if i == current parallelism:    return i
//!         if i < current and C_i < consumer lag: continue
//!         if C_i > TSF_max:               return i
//! return MaxScaleout
//! ```

use crate::clock::Timestamp;

use super::analyze::CapacityEstimates;
use super::forecasting::ForecastResult;
use super::knowledge::Knowledge;
use super::monitor::MonitorData;
use super::recovery::predict_recovery_time;
use super::DaedalusConfig;

/// Checkpoint interval assumed for replay-backlog worst case (§3.4). The
/// paper uses the job's configured 10 s interval.
pub const CHECKPOINT_INTERVAL: u64 = 10;

fn max_until(values: &[f64], secs: usize) -> f64 {
    values
        .iter()
        .take(secs.max(1))
        .copied()
        .fold(0.0, f64::max)
}

/// Outcome of the plan phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanDecision {
    /// Chosen parallelism (may equal the current one: "no rescale").
    pub target: usize,
    /// Predicted recovery time for the chosen scale-out, if one was
    /// computed (None when the early "long-lived" check short-circuits).
    pub predicted_recovery: Option<f64>,
}

/// Algorithm 1. Returns the chosen scale-out and its predicted recovery.
pub fn plan_scale_out(
    now: Timestamp,
    caps: &CapacityEstimates,
    data: &MonitorData,
    forecast: &ForecastResult,
    knowledge: &Knowledge,
    cfg: &DaedalusConfig,
    max_scaleout: usize,
) -> PlanDecision {
    let current = data.parallelism;
    let tsf = &forecast.values;
    let recent = &data.history[data.history.len().saturating_sub(60)..];

    // Long-lived decisions: right after a rescale, only interfere if the
    // current capacity is insufficient.
    if let Some(last) = knowledge.last_rescale {
        if now.saturating_sub(last) < cfg.long_lived_window {
            let until_next_loop = max_until(tsf, cfg.loop_interval as usize);
            let c_cur = caps.at(current);
            if c_cur > data.workload_avg && c_cur > until_next_loop {
                return PlanDecision { target: current, predicted_recovery: None };
            }
        }
    }

    let tsf_max_full = max_until(tsf, tsf.len());
    for i in 1..=max_scaleout {
        let c_i = caps.at(i);
        // Must cover the *observed* average workload (reactive guard).
        if c_i <= data.workload_avg {
            continue;
        }
        // Must recover within the target.
        let downtime = knowledge.anticipated_downtime(current, i);
        let rt = predict_recovery_time(c_i, recent, tsf, CHECKPOINT_INTERVAL, downtime);
        if cfg.use_recovery_constraint {
            if rt > cfg.recovery_target {
                continue;
            }
            // Must handle the workload *while* recovering.
            if c_i < max_until(tsf, rt.ceil().min(1e9) as usize) {
                continue;
            }
        }
        // Valid scale-out. Same as current → nothing to do.
        if i == current {
            return PlanDecision { target: i, predicted_recovery: Some(rt) };
        }
        // Scale-in protection: while the consumer lag exceeds the target
        // capacity the system is recovering/overloaded — wait (§3.2).
        if cfg.use_lag_guard && i < current && c_i < data.consumer_lag {
            continue;
        }
        // Long-lived: must also cover the full 15-minute forecast.
        if c_i > tsf_max_full {
            return PlanDecision { target: i, predicted_recovery: Some(rt) };
        }
    }
    PlanDecision { target: max_scaleout, predicted_recovery: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn caps_linear(per_worker: f64, parallelism: usize) -> CapacityEstimates {
        CapacityEstimates {
            per_worker: vec![per_worker; parallelism],
            current: per_worker * parallelism as f64,
            parallelism,
            avg_per_worker: per_worker,
            seen: HashMap::new(),
        }
    }

    fn data(avg: f64, lag: f64, parallelism: usize) -> MonitorData {
        MonitorData {
            now: 1_000,
            workers: vec![],
            history: vec![avg; 1800],
            workload_avg: avg,
            workload_max: avg * 1.05,
            consumer_lag: lag,
            parallelism,
        }
    }

    fn fc(vals: Vec<f64>) -> ForecastResult {
        ForecastResult {
            values: vals,
            from_model: true,
            prev_wape: None,
        }
    }

    fn knowledge() -> Knowledge {
        Knowledge::new(&crate::runtime::ArtifactMeta::default(), 30.0, 15.0)
    }

    #[test]
    fn picks_minimum_sufficient_scaleout() {
        // 5k per worker, 12k steady workload → needs ≥ 3 workers... but
        // recovery headroom pushes it to the smallest i whose capacity
        // covers workload AND recovers in 600 s. i=3 gives 15k vs 12k → 3k
        // spare; backlog ≈ 12k·10 + 12k·30 = 480k → 160 s. Valid.
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 3);
        assert!(decision.predicted_recovery.unwrap() < 600.0);
    }

    #[test]
    fn recovery_target_forces_larger_scaleout() {
        // Same but a tight 60 s recovery target: i=3 takes ~160 s → skip;
        // i=4 → 20k cap, 8k spare → backlog 480k/8k = 60 s + fits.
        let mut cfg = DaedalusConfig::default();
        cfg.recovery_target = 100.0;
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &cfg,
            18,
        );
        assert!(decision.target > 3, "decision {decision:?}");
        assert!(decision.target <= 5);
        assert!(decision.predicted_recovery.unwrap() <= 100.0);
    }

    #[test]
    fn consumer_lag_blocks_scale_in() {
        // Over-provisioned (8 × 5k for 12k load) but a huge lag: the
        // scale-in candidates (3..7) are all below the lag → wait at 8.
        let d = data(12_000.0, 10_000_000.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 8);
    }

    #[test]
    fn rising_forecast_provisions_ahead() {
        // Steady 12k now but forecast ramps to 40k → needs ≥ 9 workers
        // (45k) to cover the full forecast.
        let d = data(12_000.0, 0.0, 3);
        let rising: Vec<f64> = (0..900).map(|s| 12_000.0 + 28_000.0 * s as f64 / 900.0).collect();
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 3),
            &d,
            &fc(rising),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        // Forecast max ≈ 40k → needs ≥ 8 workers (40k capacity).
        assert!(decision.target >= 8, "decision {decision:?}");
    }

    #[test]
    fn recent_rescale_short_circuits_when_capacity_sufficient() {
        let mut k = knowledge();
        k.last_rescale = Some(900); // 100 s ago < 600 s window
        let d = data(12_000.0, 0.0, 8);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 8),
            &d,
            &fc(vec![12_000.0; 900]),
            &k,
            &DaedalusConfig::default(),
            18,
        );
        // Would otherwise scale in to 3; the long-lived check holds at 8.
        assert_eq!(decision.target, 8);
    }

    #[test]
    fn recent_rescale_does_not_block_needed_scale_out() {
        let mut k = knowledge();
        k.last_rescale = Some(900);
        // Capacity 15k < workload 20k → the short-circuit must NOT trigger.
        let d = data(20_000.0, 0.0, 3);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 3),
            &d,
            &fc(vec![20_000.0; 900]),
            &k,
            &DaedalusConfig::default(),
            18,
        );
        assert!(decision.target > 3, "decision {decision:?}");
    }

    #[test]
    fn impossible_demands_return_max_scaleout() {
        // Workload beyond any capacity → MaxScaleout (the algorithm's
        // final fallback line).
        let d = data(500_000.0, 0.0, 4);
        let decision = plan_scale_out(
            1_000,
            &caps_linear(5_000.0, 4),
            &d,
            &fc(vec![500_000.0; 900]),
            &knowledge(),
            &DaedalusConfig::default(),
            18,
        );
        assert_eq!(decision.target, 18);
    }
}
