//! Monitor phase (§3.6): pull everything one MAPE-K iteration needs out of
//! the metric store.

use crate::clock::Timestamp;
use crate::dsp::engine::SimView;
use crate::metrics::query::{self, StageMonitor, StageSnapshot, WorkerMonitor, WorkerSnapshot};
use crate::runtime::ArtifactMeta;

use super::DaedalusConfig;

/// Everything the analyze/plan phases consume this iteration.
#[derive(Debug, Clone)]
pub struct MonitorData {
    /// Collection time.
    pub now: Timestamp,
    /// Per-worker CPU/throughput snapshots (1-min moving averages).
    pub workers: Vec<WorkerSnapshot>,
    /// Per-operator-stage snapshots (staged deployments; empty on the
    /// fused pool) — busy fractions, input throughputs, queue backlogs.
    pub stages: Vec<StageSnapshot>,
    /// Current per-stage replica counts (copied from the view).
    pub stage_parallelism: Vec<usize>,
    /// Full fixed-size workload history window for the forecaster.
    pub history: Vec<f64>,
    /// Workload observed since the last loop iteration: (avg, max).
    pub workload_avg: f64,
    /// Max workload observed since the last loop iteration.
    pub workload_max: f64,
    /// Total consumer lag (tuples).
    pub consumer_lag: f64,
    /// Current job parallelism.
    pub parallelism: usize,
    /// Incremental collection state riding in the reusable buffer: the
    /// per-stage rolling windows, the per-worker handle table, and the
    /// cached `workload_rate` handle, so decision ticks never rebuild the
    /// per-stage view from scratch (pre-resolved handles, each TSDB sample
    /// read once per run).
    pub stage_monitor: StageMonitor,
    /// Cached per-worker handle table (incremental collection state).
    pub worker_monitor: WorkerMonitor,
    /// Cached `workload_rate` handle for the forecaster-input rebuild
    /// (public so sibling-module test literals can spread `..empty()`).
    pub rate_handle: Option<crate::metrics::SeriesHandle>,
}

impl MonitorData {
    /// An empty instance to use as a reusable collection buffer.
    pub fn empty() -> Self {
        Self {
            now: 0,
            workers: Vec::new(),
            stages: Vec::new(),
            stage_parallelism: Vec::new(),
            history: Vec::new(),
            workload_avg: 0.0,
            workload_max: 0.0,
            consumer_lag: 0.0,
            parallelism: 0,
            stage_monitor: StageMonitor::default(),
            worker_monitor: WorkerMonitor::new(),
            rate_handle: None,
        }
    }

    /// Collect one iteration's monitor snapshot from the view.
    pub fn collect(view: &SimView<'_>, cfg: &DaedalusConfig, meta: &ArtifactMeta) -> Self {
        let mut out = Self::empty();
        Self::collect_into(view, cfg, meta, &mut out);
        out
    }

    /// Collect into a reusable buffer: the `workers` / `history` vectors
    /// keep their capacity across MAPE-K iterations, so the steady-state
    /// monitor phase allocates nothing.
    pub fn collect_into(
        view: &SimView<'_>,
        cfg: &DaedalusConfig,
        meta: &ArtifactMeta,
        out: &mut Self,
    ) {
        let now = view.now;
        let from = now.saturating_sub(cfg.loop_interval.saturating_sub(1));
        let (workload_avg, workload_max) =
            query::workload_stats(view.tsdb, from, now).unwrap_or((0.0, 0.0));
        // Consumer lag under exactly-once is committed-offset based, so it
        // saw-tooths up to checkpoint_interval × rate even when fully
        // caught up. The minimum over one checkpoint interval is the true
        // outstanding backlog.
        let lag_id = crate::metrics::SeriesId::global("consumer_lag");
        let consumer_lag = view
            .tsdb
            .min_over(&lag_id, now.saturating_sub(15), now)
            .unwrap_or_else(|| query::consumer_lag(view.tsdb, now));
        out.now = now;
        out.worker_monitor
            .snapshots_into(view.tsdb, now, cfg.cpu_window, &mut out.workers);
        out.stage_monitor.snapshots_into(
            view.tsdb,
            now,
            cfg.cpu_window,
            view.stage_parallelism.len(),
            &mut out.stages,
        );
        out.stage_parallelism.clear();
        out.stage_parallelism.extend_from_slice(view.stage_parallelism);
        query::workload_window_into_cached(
            view.tsdb,
            &mut out.rate_handle,
            now,
            meta.window,
            &mut out.history,
        );
        out.workload_avg = workload_avg;
        out.workload_max = workload_max;
        out.consumer_lag = consumer_lag;
        out.parallelism = view.parallelism;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Tsdb;

    #[test]
    fn collects_full_iteration_view() {
        let mut db = Tsdb::new();
        for t in 0..200u64 {
            db.record_global("workload_rate", t, 10_000.0 + t as f64);
            db.record_global("consumer_lag", t, 500.0);
            for w in 0..3 {
                db.record_worker("worker_cpu", w, t, 0.5);
                db.record_worker("worker_throughput", w, t, 4_000.0);
            }
        }
        let view = SimView {
            now: 199,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(&db),
            parallelism: 3,
            ready: true,
            max_replicas: 12,
            stage_parallelism: &[],
            dropped_rescales: 0,
        };
        let cfg = DaedalusConfig::default();
        let meta = ArtifactMeta::default();
        let d = MonitorData::collect(&view, &cfg, &meta);
        assert_eq!(d.workers.len(), 3);
        assert!(d.stages.is_empty() && d.stage_parallelism.is_empty());
        assert_eq!(d.history.len(), meta.window);
        // Last loop interval covers t in [140, 199]: avg = 10_000 + 169.5.
        crate::assert_close!(d.workload_avg, 10_169.5, atol = 1e-9);
        crate::assert_close!(d.workload_max, 10_199.0, atol = 1e-9);
        crate::assert_close!(d.consumer_lag, 500.0, atol = 1e-12);
        assert_eq!(d.parallelism, 3);
    }
}
