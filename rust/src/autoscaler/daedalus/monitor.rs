//! Monitor phase (§3.6): pull everything one MAPE-K iteration needs out of
//! the metric store.

use crate::clock::Timestamp;
use crate::dsp::engine::SimView;
use crate::metrics::query::{self, WorkerSnapshot};
use crate::runtime::ArtifactMeta;

use super::DaedalusConfig;

/// Everything the analyze/plan phases consume this iteration.
#[derive(Debug, Clone)]
pub struct MonitorData {
    pub now: Timestamp,
    /// Per-worker CPU/throughput snapshots (1-min moving averages).
    pub workers: Vec<WorkerSnapshot>,
    /// Full fixed-size workload history window for the forecaster.
    pub history: Vec<f64>,
    /// Workload observed since the last loop iteration: (avg, max).
    pub workload_avg: f64,
    pub workload_max: f64,
    /// Total consumer lag (tuples).
    pub consumer_lag: f64,
    pub parallelism: usize,
}

impl MonitorData {
    pub fn collect(view: &SimView<'_>, cfg: &DaedalusConfig, meta: &ArtifactMeta) -> Self {
        let now = view.now;
        let from = now.saturating_sub(cfg.loop_interval.saturating_sub(1));
        let (workload_avg, workload_max) =
            query::workload_stats(view.tsdb, from, now).unwrap_or((0.0, 0.0));
        // Consumer lag under exactly-once is committed-offset based, so it
        // saw-tooths up to checkpoint_interval × rate even when fully
        // caught up. The minimum over one checkpoint interval is the true
        // outstanding backlog.
        let lag_id = crate::metrics::SeriesId::global("consumer_lag");
        let lag_floor = view
            .tsdb
            .values_over(&lag_id, now.saturating_sub(15), now)
            .into_iter()
            .fold(f64::MAX, f64::min);
        let consumer_lag = if lag_floor == f64::MAX {
            query::consumer_lag(view.tsdb, now)
        } else {
            lag_floor
        };
        Self {
            now,
            workers: query::worker_snapshots(view.tsdb, now, cfg.cpu_window),
            history: query::workload_window(view.tsdb, now, meta.window),
            workload_avg,
            workload_max,
            consumer_lag,
            parallelism: view.parallelism,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Tsdb;

    #[test]
    fn collects_full_iteration_view() {
        let mut db = Tsdb::new();
        for t in 0..200u64 {
            db.record_global("workload_rate", t, 10_000.0 + t as f64);
            db.record_global("consumer_lag", t, 500.0);
            for w in 0..3 {
                db.record_worker("worker_cpu", w, t, 0.5);
                db.record_worker("worker_throughput", w, t, 4_000.0);
            }
        }
        let view = SimView {
            now: 199,
            tsdb: &db,
            parallelism: 3,
            ready: true,
            max_replicas: 12,
        };
        let cfg = DaedalusConfig::default();
        let meta = ArtifactMeta::default();
        let d = MonitorData::collect(&view, &cfg, &meta);
        assert_eq!(d.workers.len(), 3);
        assert_eq!(d.history.len(), meta.window);
        // Last loop interval covers t in [140, 199]: avg = 10_000 + 169.5.
        crate::assert_close!(d.workload_avg, 10_169.5, atol = 1e-9);
        crate::assert_close!(d.workload_max, 10_199.0, atol = 1e-9);
        crate::assert_close!(d.consumer_lag, 500.0, atol = 1e-12);
        assert_eq!(d.parallelism, 3);
    }
}
