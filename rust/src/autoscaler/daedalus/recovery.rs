//! Recovery-time prediction (§3.4, Fig 6).
//!
//! `recovery = downtime + catch-up`: while the system is down, the backlog
//! is (a) everything after the last completed checkpoint — worst case one
//! full checkpoint interval of the recent workload — plus (b) whatever
//! arrives during the anticipated downtime (from the forecast). After the
//! restart, the target scale-out processes backlog + live workload at full
//! capacity; recovery ends when the cumulative *extra* capacity
//! (capacity − forecast) covers the backlog.

use crate::clock::Timestamp;

/// Predict the recovery time (seconds from the moment processing stops) if
/// the job moves to a scale-out with `capacity` while the workload follows
/// `forecast` (1 s steps). Returns `f64::INFINITY` when the horizon is too
/// short for recovery — i.e. the scale-out cannot recover in forecastable
/// time.
pub fn predict_recovery_time(
    capacity: f64,
    recent_workload: &[f64],
    forecast: &[f64],
    checkpoint_interval: u64,
    downtime_secs: f64,
) -> f64 {
    // Worst case: the failure happens right before a checkpoint completes —
    // a full interval of tuples needs reprocessing (§3.4).
    let k = (checkpoint_interval as usize).min(recent_workload.len());
    let ckpt_backlog: f64 = recent_workload[recent_workload.len() - k..].iter().sum();

    let down = downtime_secs.ceil().max(0.0) as usize;
    let arrive_during_down: f64 = forecast.iter().take(down).sum();
    let backlog = ckpt_backlog + arrive_during_down;

    let mut extra = 0.0;
    for (s, rate) in forecast.iter().enumerate().skip(down) {
        extra += capacity - rate;
        if extra >= backlog {
            return (s + 1) as f64;
        }
    }
    f64::INFINITY
}

/// Convenience: the predicted recovery time for moving `from → to` given
/// adaptive downtime estimates.
pub fn predict_for_transition(
    capacity_at_target: f64,
    recent_workload: &[f64],
    forecast: &[f64],
    checkpoint_interval: u64,
    downtime: f64,
    _from: usize,
    _to: usize,
) -> f64 {
    predict_recovery_time(
        capacity_at_target,
        recent_workload,
        forecast,
        checkpoint_interval,
        downtime,
    )
}

/// Timestamp helper: seconds since `from` (used by callers logging
/// measured vs. predicted recovery, §4.8).
pub fn elapsed(from: Timestamp, to: Timestamp) -> f64 {
    to.saturating_sub(from) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_hand_computed_case() {
        // Workload steady at 100/s, checkpoint interval 10 s → 1000 tuples
        // to replay. Downtime 30 s → 3000 more. Backlog = 4000.
        // Capacity 300/s, forecast 100/s → 200/s extra after restart.
        // Catch-up = 4000/200 = 20 s → recovery = 30 + 20 = 50 s.
        let recent = vec![100.0; 60];
        let forecast = vec![100.0; 900];
        let rt = predict_recovery_time(300.0, &recent, &forecast, 10, 30.0);
        crate::assert_close!(rt, 50.0, atol = 1.0);
    }

    #[test]
    fn higher_capacity_recovers_faster() {
        let recent = vec![1_000.0; 60];
        let forecast = vec![1_000.0; 900];
        let rt_small = predict_recovery_time(1_500.0, &recent, &forecast, 10, 30.0);
        let rt_big = predict_recovery_time(4_000.0, &recent, &forecast, 10, 30.0);
        assert!(rt_big < rt_small, "{rt_big} vs {rt_small}");
    }

    #[test]
    fn capacity_below_workload_never_recovers() {
        let recent = vec![1_000.0; 60];
        let forecast = vec![1_000.0; 900];
        let rt = predict_recovery_time(900.0, &recent, &forecast, 10, 30.0);
        assert!(rt.is_infinite());
    }

    #[test]
    fn rising_workload_delays_recovery() {
        let recent = vec![1_000.0; 60];
        let flat = vec![1_000.0; 900];
        let rising: Vec<f64> = (0..900).map(|s| 1_000.0 + s as f64).collect();
        let rt_flat = predict_recovery_time(2_000.0, &recent, &flat, 10, 30.0);
        let rt_rise = predict_recovery_time(2_000.0, &recent, &rising, 10, 30.0);
        assert!(rt_rise > rt_flat);
    }

    #[test]
    fn longer_downtime_longer_recovery() {
        let recent = vec![500.0; 60];
        let forecast = vec![500.0; 900];
        let rt15 = predict_recovery_time(1_000.0, &recent, &forecast, 10, 15.0);
        let rt60 = predict_recovery_time(1_000.0, &recent, &forecast, 10, 60.0);
        assert!(rt60 > rt15 + 40.0, "{rt60} vs {rt15}");
    }

    #[test]
    fn zero_downtime_zero_backlog_recovers_immediately() {
        let recent = vec![0.0; 60];
        let forecast = vec![0.0; 900];
        let rt = predict_recovery_time(1_000.0, &recent, &forecast, 10, 0.0);
        crate::assert_close!(rt, 1.0, atol = 1e-9);
    }
}
