//! Recovery monitoring with statistical anomaly detection (§3.5).
//!
//! Daedalus continuously tracks the difference `workload − throughput` with
//! Welford statistics. After a scaling action, a background monitor watches
//! for the difference to return inside one standard deviation of normal —
//! that moment defines the *actual* recovery time, which also refines the
//! anticipated-downtime estimates used by recovery prediction (§3.4).

use crate::clock::Timestamp;
use crate::dsp::engine::SimView;
use crate::metrics::SeriesId;

use super::knowledge::{Knowledge, ObservedRecovery};

/// Consecutive normal seconds required to declare recovery (debounce).
const NORMAL_STREAK: usize = 5;
/// Give up monitoring after this long (seconds).
const MONITOR_TIMEOUT: u64 = 1_800;
/// Anomaly threshold in standard deviations (§3.5: one σ).
const SIGMA_K: f64 = 1.0;
/// Consecutive straggler-suspect seconds before the capacity ledgers
/// quarantine their writes (see [`straggler_tick`]).
pub const STRAGGLER_STREAK: usize = 30;
/// Minimum samples in the difference statistics before straggler detection
/// can fire — a cold Welford flags everything as anomalous.
const STRAGGLER_MIN_SAMPLES: f64 = 120.0;

/// Workload/throughput difference at tick `now`, if both series have a
/// sample at exactly `now` (the engine only records throughput while
/// serving). Works on any historical tick — the event-driven manager
/// replays skipped quiet-span ticks through this from the dense TSDB,
/// with the lens re-anchored at `now`
/// ([`crate::dsp::telemetry::TelemetryLens::at`]) so a
/// replayed read is a pure function of `now` regardless of when the
/// replay happens (bitwise across engine modes).
pub fn diff_at(tsdb: crate::dsp::telemetry::TelemetryLens<'_>, now: Timestamp) -> Option<f64> {
    let (tw, w) = tsdb.last_at(&SeriesId::global("workload_rate"), now)?;
    let (tt, tp) = tsdb.last_at(&SeriesId::global("throughput"), now)?;
    (tw == now && tt == now).then_some(w - tp)
}

/// Current workload/throughput difference, if both series have a fresh
/// sample at `now`.
fn fresh_diff(view: &SimView<'_>) -> Option<f64> {
    diff_at(view.tsdb.at(view.now), view.now)
}

/// Per-second background tracking of the difference statistics. Runs only
/// in steady state (outside recovery monitoring) so recovery transients
/// don't pollute "normal".
pub fn track(knowledge: &mut Knowledge, view: &SimView<'_>) {
    if let Some(d) = fresh_diff(view) {
        knowledge.anomaly.push_scalar(d);
    }
}

/// One tick of straggler detection (gray failures: a degraded worker is
/// detectable *only* as a persistent positive workload/throughput gap —
/// there is no restart to observe). A tick is suspect when the job serves,
/// the difference statistics are warm, and the gap is positive and
/// anomalous; [`STRAGGLER_STREAK`] consecutive suspect ticks quarantine the
/// knowledge-ledger writes ([`Knowledge::straggler_suspect`]) until the
/// gap normalizes. The transition into quarantine is counted in
/// `Knowledge::quarantined_windows`.
pub fn straggler_tick(knowledge: &mut Knowledge, ready: bool, diff: Option<f64>) {
    let suspect = ready
        && knowledge.anomaly.count >= STRAGGLER_MIN_SAMPLES
        && matches!(diff, Some(d) if d > 0.0 && knowledge.anomaly.is_anomalous(d, SIGMA_K));
    if suspect {
        knowledge.straggler_streak += 1;
        if knowledge.straggler_streak == STRAGGLER_STREAK {
            knowledge.quarantined_windows += 1;
        }
    } else {
        knowledge.straggler_streak = 0;
    }
}

/// Background monitor started by the execute phase after a rescale.
#[derive(Debug, Clone)]
pub struct RecoveryMonitor {
    started: Timestamp,
    scale_out: bool,
    serving_since: Option<Timestamp>,
    normal_streak: usize,
}

impl RecoveryMonitor {
    /// Begin monitoring the recovery following a rescale issued at `now`.
    pub fn start(now: Timestamp, scale_out: bool) -> Self {
        Self {
            started: now,
            scale_out,
            serving_since: None,
            normal_streak: 0,
        }
    }

    /// One tick of monitoring. Returns `true` when finished (recovered or
    /// timed out); on recovery the observation is folded into Knowledge.
    pub fn update(&mut self, knowledge: &mut Knowledge, view: &SimView<'_>) -> bool {
        self.update_at(knowledge, view.now, view.ready, fresh_diff(view))
    }

    /// [`RecoveryMonitor::update`] with the view decomposed into its three
    /// inputs — the event-driven manager replays skipped quiet-span ticks
    /// through this (`diff` from [`diff_at`] on the dense TSDB), making
    /// the catch-up bit-identical to per-tick calls.
    pub fn update_at(
        &mut self,
        knowledge: &mut Knowledge,
        now: Timestamp,
        ready: bool,
        diff: Option<f64>,
    ) -> bool {
        // Downtime observation: first tick the pods serve again. Checked
        // before the timeout so a restart that outlasts MONITOR_TIMEOUT
        // still feeds the downtime EMA — only the *recovery* observation
        // is abandoned on timeout.
        if self.serving_since.is_none() && ready {
            self.serving_since = Some(now);
            knowledge.observe_downtime(self.scale_out, now.saturating_sub(self.started) as f64);
        }
        if now.saturating_sub(self.started) > MONITOR_TIMEOUT {
            return true; // give up on observing the recovery
        }
        let Some(_) = self.serving_since else {
            return false;
        };
        // Anomaly check on the fresh difference.
        let Some(d) = diff else {
            return false;
        };
        if knowledge.anomaly.is_anomalous(d, SIGMA_K) {
            self.normal_streak = 0;
        } else {
            self.normal_streak += 1;
        }
        if self.normal_streak >= NORMAL_STREAK {
            let recovery = now.saturating_sub(self.started) as f64;
            knowledge.recoveries.push(ObservedRecovery {
                rescale_at: self.started,
                downtime_secs: self
                    .serving_since
                    .map(|s| s.saturating_sub(self.started) as f64)
                    .unwrap_or(0.0),
                recovery_secs: recovery,
                scale_out: self.scale_out,
            });
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Tsdb;
    use crate::runtime::ArtifactMeta;

    fn knowledge_with_normal() -> Knowledge {
        let mut k = Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0);
        // Normal operation: diff ≈ 0 ± 50.
        for i in 0..600 {
            k.anomaly.push_scalar(((i % 11) as f64 - 5.0) * 10.0);
        }
        k
    }

    fn view_at(db: &Tsdb, now: Timestamp, ready: bool) -> SimView<'_> {
        SimView {
            now,
            tsdb: crate::dsp::telemetry::TelemetryLens::transparent(db),
            parallelism: 4,
            ready,
            max_replicas: 12,
            stage_parallelism: &[],
            dropped_rescales: 0,
        }
    }

    #[test]
    fn detects_recovery_after_catchup() {
        let mut k = knowledge_with_normal();
        let mut db = Tsdb::new();
        let mut mon = RecoveryMonitor::start(100, true);

        // 30 s downtime: workload recorded, no throughput.
        for t in 100..130 {
            db.record_global("workload_rate", t, 10_000.0);
            assert!(!mon.update(&mut k, &view_at(&db, t, false)));
        }
        // Catch-up: big positive diff (throughput exceeds workload is
        // negative diff — also anomalous vs N(0,50)).
        for t in 130..200 {
            db.record_global("workload_rate", t, 10_000.0);
            db.record_global("throughput", t, 22_000.0);
            assert!(!mon.update(&mut k, &view_at(&db, t, true)), "t={t}");
        }
        // Normal again.
        let mut done_at = None;
        for t in 200..260 {
            db.record_global("workload_rate", t, 10_000.0);
            db.record_global("throughput", t, 10_000.0);
            if mon.update(&mut k, &view_at(&db, t, true)) {
                done_at = Some(t);
                break;
            }
        }
        let done = done_at.expect("recovery detected");
        assert!(done >= 204 && done <= 210, "done at {done}");
        assert_eq!(k.recoveries.len(), 1);
        let rec = k.recoveries[0];
        crate::assert_close!(rec.downtime_secs, 30.0, atol = 1.0);
        assert!(rec.recovery_secs >= 100.0);
        // Downtime EMA moved from 30 toward the observed 30 (unchanged).
        crate::assert_close!(k.downtime_out, 30.0, atol = 0.5);
    }

    #[test]
    fn slow_restart_still_observes_downtime_at_timeout() {
        // Regression: a restart that only resumes serving after
        // MONITOR_TIMEOUT has elapsed must still feed the downtime EMA
        // before the monitor gives up on the recovery observation.
        let mut k = knowledge_with_normal();
        let db = Tsdb::new();
        let mut mon = RecoveryMonitor::start(100, true);
        let before = k.downtime_out;
        // Down the whole window …
        assert!(!mon.update(&mut k, &view_at(&db, 1_000, false)));
        // … and serving resumes only at started + 1 801 (past the timeout).
        assert!(mon.update(&mut k, &view_at(&db, 100 + 1_801, true)));
        assert!(
            k.downtime_out > before,
            "downtime EMA did not learn: {} -> {}",
            before,
            k.downtime_out
        );
        // The recovery observation itself is still abandoned.
        assert!(k.recoveries.is_empty());
    }

    #[test]
    fn timeout_ends_monitoring() {
        let mut k = knowledge_with_normal();
        let db = Tsdb::new();
        let mut mon = RecoveryMonitor::start(100, true);
        assert!(!mon.update(&mut k, &view_at(&db, 200, false)));
        assert!(mon.update(&mut k, &view_at(&db, 100 + 1_801, false)));
        assert!(k.recoveries.is_empty());
    }

    /// A gray failure shows up as a persistent positive anomalous gap: the
    /// streak must build to the quarantine threshold, flag the window
    /// exactly once, and release as soon as the gap normalizes.
    #[test]
    fn straggler_streak_quarantines_and_releases() {
        let mut k = knowledge_with_normal(); // normal ≈ 0 ± 50, 600 samples
        assert!(!k.straggler_suspect());
        // A degraded worker leaves a persistent ~2 000-tuple gap.
        for _ in 0..STRAGGLER_STREAK {
            assert!(!k.straggler_suspect());
            straggler_tick(&mut k, true, Some(2_000.0));
        }
        assert!(k.straggler_suspect());
        assert_eq!(k.quarantined_windows, 1);
        // Staying suspect does not re-count the window.
        straggler_tick(&mut k, true, Some(2_000.0));
        assert_eq!(k.quarantined_windows, 1);
        // The gap normalizes → quarantine releases immediately.
        straggler_tick(&mut k, true, Some(10.0));
        assert!(!k.straggler_suspect());
        assert_eq!(k.straggler_streak, 0);

        // Non-serving ticks and negative (catch-up) gaps never count.
        let mut k2 = knowledge_with_normal();
        for _ in 0..2 * STRAGGLER_STREAK {
            straggler_tick(&mut k2, false, Some(2_000.0));
            straggler_tick(&mut k2, true, Some(-2_000.0));
        }
        assert!(!k2.straggler_suspect());
        assert_eq!(k2.quarantined_windows, 0);

        // A cold Welford (fresh knowledge) cannot fire.
        let mut cold = Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0);
        for _ in 0..2 * STRAGGLER_STREAK {
            straggler_tick(&mut cold, true, Some(2_000.0));
        }
        assert!(!cold.straggler_suspect());
    }

    #[test]
    fn track_ignores_stale_throughput() {
        let mut k = Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0);
        let mut db = Tsdb::new();
        db.record_global("workload_rate", 10, 5_000.0);
        db.record_global("throughput", 5, 5_000.0); // stale
        track(&mut k, &view_at(&db, 10, false));
        assert_eq!(k.anomaly.count, 0.0);
        db.record_global("throughput", 10, 5_000.0);
        track(&mut k, &view_at(&db, 10, true));
        assert_eq!(k.anomaly.count, 1.0);
    }
}
