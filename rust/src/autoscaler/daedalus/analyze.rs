//! Analyze phase, capacity half (§3.1): per-worker CPU↔throughput
//! regression through the AOT capacity artifact, skew-aware capacity
//! targets, and scale-out capacity estimation.
//!
//! Skew handling: a worker starved by key distribution never reaches 100 %
//! CPU; its *expected maximum* CPU is proportional to the hottest worker
//! (Fig 4). So the regression for worker *i* is evaluated at
//! `cpu_target · cpu_i / max_j cpu_j`.
//!
//! Scale-out estimation: the capacity at the *current* scale-out is the sum
//! of per-worker estimates; *seen* scale-outs reuse their last observed
//! estimate; unseen ones use `average worker capacity × n` (§3.1).

use std::collections::HashMap;

use crate::runtime::{ArtifactMeta, ComputeBackend};

use super::knowledge::Knowledge;
use super::monitor::MonitorData;

/// Capacity estimates for all scale-outs, produced each iteration.
#[derive(Debug, Clone)]
pub struct CapacityEstimates {
    /// Per-worker capacity at the skew-aware CPU target (current workers).
    pub per_worker: Vec<f64>,
    /// Estimated capacity at the current scale-out.
    pub current: f64,
    /// Current parallelism the estimate belongs to.
    pub parallelism: usize,
    /// Mean per-worker capacity.
    pub avg_per_worker: f64,
    /// Last observed estimates for seen scale-outs.
    pub seen: HashMap<usize, f64>,
}

impl CapacityEstimates {
    /// Capacity estimate at scale-out `n` (observed-over-predicted rule).
    pub fn at(&self, n: usize) -> f64 {
        if n == self.parallelism {
            return self.current;
        }
        match self.seen.get(&n) {
            Some(c) => *c,
            None => self.avg_per_worker * n as f64,
        }
    }
}

/// The capacity analyzer (owns only static shape info; all mutable state
/// lives in [`Knowledge`]).
pub struct Analyzer {
    meta: ArtifactMeta,
}

impl Analyzer {
    /// Analyzer for artifacts of the given shape.
    pub fn new(meta: ArtifactMeta) -> Self {
        Self { meta }
    }

    /// Fold this iteration's observations through the capacity artifact and
    /// derive capacity estimates.
    pub fn update_capacity(
        &self,
        backend: &ComputeBackend,
        knowledge: &mut Knowledge,
        data: &MonitorData,
        cpu_target: f64,
        skew_aware: bool,
    ) -> CapacityEstimates {
        let mw = self.meta.max_workers;
        let b = self.meta.obs_block;
        let mut xs = vec![0.0f32; mw * b];
        let mut ys = vec![0.0f32; mw * b];
        let mut mask = vec![0.0f32; mw * b];
        let mut tgt = vec![1.0f32; mw];

        let max_cpu = data
            .workers
            .iter()
            .map(|w| w.cpu)
            .fold(0.0, f64::max)
            .max(1e-6);
        // Self-calibrating saturation point: the hottest worker is
        // extrapolated to the highest CPU ever observed (floored at 0.85
        // until saturation has actually been seen, capped by the config).
        knowledge.max_cpu_seen = knowledge.max_cpu_seen.max(max_cpu).min(1.0);
        let cpu_sat = knowledge.max_cpu_seen.max(0.85).min(cpu_target);
        for snap in &data.workers {
            if snap.worker >= mw {
                continue;
            }
            // One (cpu, throughput) observation per worker per loop — the
            // paper shows ~60 s of data per loop already gives an accurate
            // regression (§3.1).
            let slot = snap.worker * b;
            xs[slot] = snap.cpu as f32;
            ys[slot] = snap.throughput as f32;
            mask[slot] = 1.0;
            // Ablation: without skew awareness every worker is assumed to
            // reach the full saturation CPU (prior-work assumption).
            let ratio = if skew_aware {
                (snap.cpu / max_cpu).clamp(0.05, 1.0)
            } else {
                1.0
            };
            tgt[snap.worker] = (cpu_sat * ratio) as f32;
        }

        let out = backend
            .capacity_update(&knowledge.capacity_state, &xs, &ys, &mask, &tgt)
            .expect("capacity artifact execution failed");
        knowledge.capacity_state = out.state;

        let n = data.parallelism.max(1);
        let per_worker: Vec<f64> = (0..n.min(mw))
            .map(|w| out.capacities[w] as f64)
            .collect();
        let current: f64 = per_worker.iter().sum();
        let avg = if per_worker.is_empty() {
            0.0
        } else {
            current / per_worker.len() as f64
        };
        // Ledger quarantine: while the anomaly tracker flags a straggler
        // window (a gray-degraded worker drags throughput down with no
        // restart to observe) or the manager flags the monitor window as
        // telemetry-suspect, the estimate still feeds *this* iteration's
        // planning but is not remembered as the capacity of a healthy
        // deployment at scale-out `n`.
        if !knowledge.capacity_quarantined() {
            knowledge.seen_capacity.insert(n, current);
            knowledge.capacity_history.push((data.now, n, current));
        }

        CapacityEstimates {
            per_worker,
            current,
            parallelism: n,
            avg_per_worker: avg,
            seen: knowledge.seen_capacity.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::query::WorkerSnapshot;

    fn data_with(workers: Vec<WorkerSnapshot>, parallelism: usize) -> MonitorData {
        MonitorData {
            now: 120,
            workers,
            history: vec![10_000.0; 1800],
            workload_avg: 10_000.0,
            workload_max: 11_000.0,
            parallelism,
            ..MonitorData::empty()
        }
    }

    fn snap(worker: usize, cpu: f64, tput: f64) -> WorkerSnapshot {
        WorkerSnapshot {
            worker,
            cpu,
            throughput: tput,
        }
    }

    #[test]
    fn capacity_estimates_accumulate_over_loops() {
        let backend = ComputeBackend::native();
        let meta = backend.meta().clone();
        let analyzer = Analyzer::new(meta.clone());
        let mut k = Knowledge::new(&meta, 30.0, 15.0);

        // Two loops with slightly different CPU levels → regression forms.
        let d1 = data_with(vec![snap(0, 0.5, 2_500.0), snap(1, 0.5, 2_500.0)], 2);
        analyzer.update_capacity(&backend, &mut k, &d1, 1.0, true);
        let d2 = data_with(vec![snap(0, 0.8, 4_000.0), snap(1, 0.8, 4_000.0)], 2);
        let est = analyzer.update_capacity(&backend, &mut k, &d2, 1.0, true);
        // Linear through (0.5, 2500) and (0.8, 4000), evaluated at the
        // calibration floor 0.85 (no saturation seen yet) → 4250.
        crate::assert_close!(est.per_worker[0], 4_250.0, rtol = 0.02);
        crate::assert_close!(est.current, 8_500.0, rtol = 0.02);
    }

    #[test]
    fn skew_aware_targets_scale_with_hottest_worker() {
        let backend = ComputeBackend::native();
        let meta = backend.meta().clone();
        let analyzer = Analyzer::new(meta.clone());
        let mut k = Knowledge::new(&meta, 30.0, 15.0);

        // Worker 1 is the hottest (0.8); worker 0 is starved at 0.4 → its
        // expected max CPU is 0.5 · target.
        let d1 = data_with(vec![snap(0, 0.3, 1_500.0), snap(1, 0.6, 3_000.0)], 2);
        analyzer.update_capacity(&backend, &mut k, &d1, 1.0, true);
        let d2 = data_with(vec![snap(0, 0.4, 2_000.0), snap(1, 0.8, 4_000.0)], 2);
        let est = analyzer.update_capacity(&backend, &mut k, &d2, 1.0, true);
        // Both workers process 5000·cpu; the hottest extrapolates to the
        // 0.85 calibration floor → 4250; the starved one only to half that
        // CPU (proportional skew) → 2125.
        crate::assert_close!(est.per_worker[1], 4_250.0, rtol = 0.02);
        crate::assert_close!(est.per_worker[0], 2_125.0, rtol = 0.02);
    }

    #[test]
    fn unseen_scaleouts_use_average_seen_use_memory() {
        let backend = ComputeBackend::native();
        let meta = backend.meta().clone();
        let analyzer = Analyzer::new(meta.clone());
        let mut k = Knowledge::new(&meta, 30.0, 15.0);
        let d1 = data_with(vec![snap(0, 0.5, 2_500.0), snap(1, 0.5, 2_500.0)], 2);
        analyzer.update_capacity(&backend, &mut k, &d1, 1.0, true);
        let d2 = data_with(vec![snap(0, 0.8, 4_000.0), snap(1, 0.8, 4_000.0)], 2);
        let est = analyzer.update_capacity(&backend, &mut k, &d2, 1.0, true);

        // Unseen n = 6 → avg · 6 ≈ 25.5k (at the 0.85 calibration floor).
        crate::assert_close!(est.at(6), 25_500.0, rtol = 0.03);
        // Seen n = 2 → remembered estimate.
        crate::assert_close!(est.at(2), est.current, atol = 1e-9);
        assert!(k.seen_capacity.contains_key(&2));
    }
}
