//! The K in MAPE-K: state shared between the phases (§3.6).
//!
//! Holds the per-worker regression state (flowing through the AOT capacity
//! artifact), capacity estimates per seen scale-out, forecast bookkeeping
//! (for the WAPE gate), the anomaly-detection statistics, the adaptive
//! downtime estimates, and the scaling-action history.

use std::collections::HashMap;

use crate::clock::Timestamp;
use crate::runtime::{ArtifactMeta, CapacityState};
use crate::stats::Welford;

/// A forecast issued at some loop iteration (for later WAPE evaluation).
#[derive(Debug, Clone)]
pub struct IssuedForecast {
    /// Loop-iteration time the forecast was issued at.
    pub issued_at: Timestamp,
    /// Predicted workload for seconds `issued_at+1 ..= issued_at+horizon`.
    pub values: Vec<f64>,
    /// Whether this was the ARI artifact (true) or the linear fallback.
    pub from_model: bool,
}

/// An observed recovery after a scaling action (§3.5).
#[derive(Debug, Clone, Copy)]
pub struct ObservedRecovery {
    /// When the rescale was executed.
    pub rescale_at: Timestamp,
    /// Observed restart downtime (s).
    pub downtime_secs: f64,
    /// Seconds from restart until lag returned to normal.
    pub recovery_secs: f64,
    /// Whether the action grew the deployment.
    pub scale_out: bool,
}

/// Shared knowledge base.
pub struct Knowledge {
    /// Welford regression state for up to `max_workers` workers (artifact
    /// layout `[max_workers, 5]`).
    pub capacity_state: CapacityState,
    /// Latest capacity estimate per *seen* scale-out (paper §3.1: observed
    /// estimations are preferred over predicted ones).
    pub seen_capacity: HashMap<usize, f64>,
    /// Per-stage observed-capacity ledger on staged deployments:
    /// `(stage, replicas) → stage input capacity` (same
    /// observed-over-predicted rule, per operator).
    pub stage_capacity: HashMap<(usize, usize), f64>,
    /// Config-keyed extension of the per-stage ledger (ISSUE 10):
    /// `(stage, replicas, config fingerprint) → running capacity stats`.
    /// The fingerprint is `RuntimeConfig::fingerprint()` — a quantized key,
    /// so nearby configs share a cell. Written unconditionally (behind the
    /// same [`Self::capacity_quarantined`] gate as the legacy ledger) but
    /// only *read* by config-aware planners (`use_config_ledger`), keeping
    /// scale-out-only Daedalus bit-identical.
    pub stage_config_capacity: HashMap<(usize, usize, u64), Welford>,
    /// Fingerprint of the runtime config the current observations are
    /// running under (updated by the manager; 0 until first set).
    pub active_config_fingerprint: u64,
    /// Most recent forecast, for the next loop's WAPE check.
    pub last_forecast: Option<IssuedForecast>,
    /// Consecutive poor forecasts (≥ threshold triggers retrain).
    pub bad_forecast_streak: usize,
    /// Number of (simulated) model retrains.
    pub retrain_count: usize,
    /// Highest per-worker CPU (1-min MA) ever observed — the calibration
    /// point for "expected maximum CPU utilization" (§3.1): engines like
    /// Kafka Streams saturate well below 100 % CPU, so extrapolating to
    /// 1.0 would overestimate capacity by ~30 %.
    pub max_cpu_seen: f64,
    /// Running stats of (workload − throughput) for anomaly detection.
    pub anomaly: Welford,
    /// Consecutive tracked seconds the workload/throughput difference has
    /// looked straggler-like (see `anomaly::straggler_tick`).
    pub straggler_streak: usize,
    /// Times the straggler streak crossed the quarantine threshold — each
    /// is one window whose capacity observations were kept out of the
    /// ledgers (reports/diagnostics).
    pub quarantined_windows: usize,
    /// Whether the current monitor window overlaps an active telemetry
    /// fault (set by the manager each loop, hardened mode only): capacity
    /// observations are quarantined exactly like straggler windows.
    telemetry_suspect: bool,
    /// Rising edges of the telemetry quarantine — each is one degraded
    /// span whose capacity observations were kept out of the ledgers.
    pub telemetry_quarantined_windows: usize,
    /// Adaptive anticipated downtimes (§3.4), refined from observations.
    pub downtime_out: f64,
    /// Anticipated scale-in downtime (s), refined from observations.
    pub downtime_in: f64,
    /// Time of the last executed scaling action.
    pub last_rescale: Option<Timestamp>,
    /// Number of executed scaling actions.
    pub rescale_count: usize,
    /// Completed recovery observations.
    pub recoveries: Vec<ObservedRecovery>,
    /// Predicted recovery times at the moment each rescale was executed
    /// (§4.8: predicted vs. measured comparison).
    pub predicted_recoveries: Vec<(Timestamp, f64)>,
    /// WAPE values measured against realized workload (diagnostics, §4.8).
    pub wape_history: Vec<f64>,
    /// Capacity-estimate history (t, scale-out, estimate) for validation.
    pub capacity_history: Vec<(Timestamp, usize, f64)>,
}

impl Knowledge {
    /// Fresh knowledge base with the configured initial downtimes.
    pub fn new(meta: &ArtifactMeta, downtime_out: f64, downtime_in: f64) -> Self {
        Self {
            capacity_state: CapacityState::zeros(meta.max_workers),
            seen_capacity: HashMap::new(),
            stage_capacity: HashMap::new(),
            stage_config_capacity: HashMap::new(),
            active_config_fingerprint: 0,
            last_forecast: None,
            bad_forecast_streak: 0,
            retrain_count: 0,
            max_cpu_seen: 0.0,
            anomaly: Welford::new(),
            straggler_streak: 0,
            quarantined_windows: 0,
            telemetry_suspect: false,
            telemetry_quarantined_windows: 0,
            downtime_out,
            downtime_in,
            last_rescale: None,
            rescale_count: 0,
            recoveries: Vec::new(),
            predicted_recoveries: Vec::new(),
            wape_history: Vec::new(),
            capacity_history: Vec::new(),
        }
    }

    /// Anticipated downtime for a transition `from → to` (worst case for a
    /// failure is the scale-out path, §3.4).
    pub fn anticipated_downtime(&self, from: usize, to: usize) -> f64 {
        if to >= from {
            self.downtime_out
        } else {
            self.downtime_in
        }
    }

    /// Fold an observed downtime into the adaptive estimate (EMA; §3.5
    /// "this generally yields more accurate recovery time predictions over
    /// time").
    pub fn observe_downtime(&mut self, scale_out: bool, secs: f64) {
        const ALPHA: f64 = 0.3;
        let slot = if scale_out {
            &mut self.downtime_out
        } else {
            &mut self.downtime_in
        };
        *slot = (1.0 - ALPHA) * *slot + ALPHA * secs;
    }

    /// Reset per-worker regression state (on rescale the pods are new and
    /// the data distribution changed; §3.1 monitors each worker freshly).
    pub fn reset_capacity_state(&mut self) {
        self.capacity_state.reset_all();
    }

    /// Whether the current window is straggler-suspect (a gray failure or
    /// similar partial degradation): the capacity ledgers quarantine their
    /// writes until the workload/throughput difference normalizes, so a
    /// degraded worker's throughput is never remembered as the capacity of
    /// a healthy deployment. Planning still uses the fresh in-loop
    /// estimates — only *persistence* is gated.
    pub fn straggler_suspect(&self) -> bool {
        self.straggler_streak >= super::anomaly::STRAGGLER_STREAK
    }

    /// Update the telemetry quarantine flag (manager-driven, per loop).
    /// A rising edge counts one quarantined window for diagnostics.
    pub fn set_telemetry_suspect(&mut self, suspect: bool) {
        if suspect && !self.telemetry_suspect {
            self.telemetry_quarantined_windows += 1;
        }
        self.telemetry_suspect = suspect;
    }

    /// Whether the current monitor window is telemetry-suspect (ISSUE 9):
    /// a metric fault overlapped the window the capacity observation was
    /// computed from.
    pub fn telemetry_suspect(&self) -> bool {
        self.telemetry_suspect
    }

    /// Combined capacity-ledger quarantine: straggler-suspect (gray
    /// failure, PR 7) or telemetry-suspect (corruption/staleness in the
    /// monitor window). Planning still uses the fresh in-loop estimates —
    /// only *persistence* into the ledgers is gated.
    pub fn capacity_quarantined(&self) -> bool {
        self.straggler_suspect() || self.telemetry_suspect
    }

    /// Fold a per-stage capacity observation into the config-keyed ledger
    /// under the active fingerprint. Shares the quarantine gate with the
    /// legacy `(stage, replicas)` ledger: suspect windows are never
    /// remembered as the capacity of a healthy deployment under *any*
    /// config.
    pub fn observe_config_capacity(&mut self, stage: usize, replicas: usize, capacity: f64) {
        if self.capacity_quarantined() {
            return;
        }
        self.stage_config_capacity
            .entry((stage, replicas, self.active_config_fingerprint))
            .or_default()
            .push_scalar(capacity);
    }

    /// Mean observed capacity of `(stage, replicas)` under the active
    /// config fingerprint, if any observation exists.
    pub fn config_capacity(&self, stage: usize, replicas: usize) -> Option<f64> {
        self.stage_config_capacity
            .get(&(stage, replicas, self.active_config_fingerprint))
            .filter(|w| w.count >= 1.0)
            .map(|w| w.mean_x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knowledge() -> Knowledge {
        Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0)
    }

    #[test]
    fn anticipated_downtime_direction() {
        let k = knowledge();
        assert_eq!(k.anticipated_downtime(4, 8), 30.0);
        assert_eq!(k.anticipated_downtime(8, 4), 15.0);
        // Failure (same → same) uses the conservative scale-out estimate.
        assert_eq!(k.anticipated_downtime(4, 4), 30.0);
    }

    #[test]
    fn downtime_adapts_toward_observations() {
        let mut k = knowledge();
        for _ in 0..20 {
            k.observe_downtime(true, 50.0);
        }
        assert!((k.downtime_out - 50.0).abs() < 1.0, "{}", k.downtime_out);
        assert_eq!(k.downtime_in, 15.0); // untouched
    }

    #[test]
    fn config_ledger_keys_by_active_fingerprint_and_respects_quarantine() {
        let mut k = knowledge();
        k.active_config_fingerprint = 7;
        k.observe_config_capacity(1, 4, 1000.0);
        k.observe_config_capacity(1, 4, 1100.0);
        assert_eq!(k.config_capacity(1, 4), Some(1050.0));
        // A different active fingerprint sees a different (empty) cell.
        k.active_config_fingerprint = 9;
        assert_eq!(k.config_capacity(1, 4), None);
        // Quarantined windows never reach the ledger.
        k.set_telemetry_suspect(true);
        k.observe_config_capacity(1, 4, 9999.0);
        assert_eq!(k.config_capacity(1, 4), None);
        k.set_telemetry_suspect(false);
        k.observe_config_capacity(1, 4, 2000.0);
        assert_eq!(k.config_capacity(1, 4), Some(2000.0));
        // The fingerprint-7 cell is untouched throughout.
        k.active_config_fingerprint = 7;
        assert_eq!(k.config_capacity(1, 4), Some(1050.0));
    }

    #[test]
    fn capacity_state_resets() {
        let mut k = knowledge();
        // Simulate some accumulated state.
        k.capacity_state = CapacityState::from_vec(vec![1.0; 32 * 5], 32).unwrap();
        k.reset_capacity_state();
        assert!(k.capacity_state.as_slice().iter().all(|v| *v == 0.0));
    }
}
