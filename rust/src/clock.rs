//! Virtual/wall clock abstraction.
//!
//! The coordinator is written against [`Clock`] so the same MAPE-K code can
//! drive a real cluster in wall time or the simulator in virtual time. All
//! experiments use [`VirtualClock`]: a 6-hour paper run executes in seconds
//! and is perfectly reproducible.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Seconds since job start.
pub type Timestamp = u64;

/// A monotonic clock in whole seconds.
pub trait Clock: Send + Sync {
    /// Current time (seconds since epoch-of-run).
    fn now(&self) -> Timestamp;
}

/// Simulation-driven clock: the engine advances it one tick at a time.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: AtomicU64,
}

impl VirtualClock {
    /// Fresh clock at t = 0, shared behind an `Arc`.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Advance to an absolute timestamp (monotonicity enforced).
    pub fn advance_to(&self, t: Timestamp) {
        let prev = self.now.swap(t, Ordering::SeqCst);
        debug_assert!(t >= prev, "clock moved backwards: {prev} -> {t}");
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Timestamp {
        self.now.load(Ordering::SeqCst)
    }
}

/// Wall clock relative to construction time (for live deployments).
#[derive(Debug)]
pub struct WallClock {
    start: std::time::Instant,
}

impl WallClock {
    /// Clock anchored at the current instant.
    pub fn new() -> Self {
        Self {
            start: std::time::Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Timestamp {
        self.start.elapsed().as_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(10);
        assert_eq!(c.now(), 10);
        c.advance_to(11);
        assert_eq!(c.now(), 11);
    }

    #[test]
    fn wall_clock_starts_at_zero() {
        let c = WallClock::new();
        assert!(c.now() < 2);
    }
}
