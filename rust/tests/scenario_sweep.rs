//! Scenario-matrix sweep integration tests: the ≥12-run parallel matrix
//! and its determinism proof (identical trace digests across repeated runs
//! and across thread counts).

use daedalus::experiments::scenarios::{run_sweep, ScenarioRegistry, SweepOptions};

fn matrix(reg: &ScenarioRegistry) -> Vec<&daedalus::experiments::Scenario> {
    reg.select(&[
        "flink-wordcount-sine",
        "flink-wordcount-flash-crowd",
        "kstreams-wordcount-diurnal-drift",
    ])
    .unwrap()
}

#[test]
fn twelve_run_matrix_is_deterministic_across_runs_and_thread_counts() {
    let reg = ScenarioRegistry::builtin(1_200, &[1, 2]);
    let sel = matrix(&reg);
    let opts = |threads| SweepOptions {
        threads,
        trace_stride: 60,
        approaches: Some(vec!["daedalus".into(), "static-6".into()]),
    };
    // 3 scenarios × 2 approaches × 2 seeds = 12 parallel runs.
    let parallel = run_sweep(&sel, &opts(4)).unwrap();
    assert_eq!(parallel.runs.len(), 12);

    // Same matrix again with the same seeds: identical digests, bit for bit.
    let again = run_sweep(&sel, &opts(4)).unwrap();
    // And once more on a single thread: scheduling cannot matter.
    let serial = run_sweep(&sel, &opts(1)).unwrap();
    for ((a, b), c) in parallel
        .runs
        .iter()
        .zip(&again.runs)
        .zip(&serial.runs)
    {
        assert_eq!(a.unit, b.unit);
        assert_eq!(a.unit, c.unit);
        assert_eq!(a.digest, b.digest, "rerun digest drift for {:?}", a.unit);
        assert_eq!(a.digest, c.digest, "thread-count digest drift for {:?}", a.unit);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.worker_seconds, b.worker_seconds);
    }

    // Different seeds genuinely change the traces (the digest is not a
    // constant function).
    assert_ne!(parallel.runs[0].digest, parallel.runs[1].digest);
}

#[test]
fn new_shapes_are_exercised_through_the_registry_by_name() {
    let reg = ScenarioRegistry::builtin(1_200, &[1]);
    for name in [
        "flink-wordcount-flash-crowd",
        "flink-wordcount-diurnal-drift",
        "flink-wordcount-outage-backfill",
    ] {
        let sel = reg.select(&[name]).unwrap();
        let opts = SweepOptions {
            threads: 2,
            trace_stride: 60,
            approaches: Some(vec!["hpa-80".into()]),
        };
        let report = run_sweep(&sel, &opts).unwrap();
        assert_eq!(report.runs.len(), 1);
        let run = &report.runs[0];
        assert_eq!(run.unit.scenario, name);
        // The run processed real traffic and produced a full trace.
        assert!(run.worker_seconds > 0.0);
        assert_eq!(run.trace.points.len(), 20);
        assert!(run.trace.points.iter().all(|p| p.replicas >= 1));
    }
}

#[test]
fn failure_scenarios_inject_failures_into_the_trace() {
    let reg = ScenarioRegistry::builtin(2_400, &[1]);
    let sel = reg.select(&["flink-wordcount-sine-failstorm3"]).unwrap();
    let opts = SweepOptions {
        threads: 1,
        trace_stride: 60,
        approaches: Some(vec!["static-8".into()]),
    };
    let report = run_sweep(&sel, &opts).unwrap();
    let run = &report.runs[0];
    let failures = run
        .trace
        .events
        .iter()
        .filter(|e| e.failure)
        .count();
    assert_eq!(failures, 3, "events: {:?}", run.trace.events);
}
