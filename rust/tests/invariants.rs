//! Conservation invariants of the DSP substrate under adversarial
//! schedules — hand-rolled property-style tests (proptest is unavailable
//! offline): seeded random rescale/failure storms across every workload
//! shape, checking after every phase that no tuple is lost or invented.
//!
//! Invariants:
//! 1. `produced == consumed + backlog` (offset bookkeeping, exact).
//! 2. `committed ≤ consumed ≤ produced` (exactly-once ordering).
//! 3. After a completed checkpoint, `committed == consumed` and the
//!    Kafka-visible lag equals the backlog.
//! 4. Every latency sample's weight comes from a consumed chunk: pooled
//!    latency weight equals the integral of recorded throughput.
//! 5. Queue mass equals backlog per partition (`check_invariants`).

use daedalus::dsp::{
    CorruptionKind, EngineProfile, FaultEvent, FaultTimeline, MergePolicy, QueuePolicy,
    SeriesPattern, SimConfig, Simulation, StageModel, TelemetryFaultEvent, TelemetryFaultTimeline,
};
use daedalus::experiments::ScenarioRegistry;
use daedalus::jobs::{JobProfile, Topology};
use daedalus::metrics::SeriesId;
use daedalus::stats::Rng;
use daedalus::workload::ShapeKind;

fn assert_conservation(sim: &Simulation) {
    sim.check_invariants();
    let produced = sim.total_produced();
    let consumed = sim.total_consumed();
    let committed = sim.total_committed();
    let backlog = sim.total_backlog();
    let tol = 1e-6 * produced.max(1.0);
    assert!(
        (produced - consumed - backlog).abs() < tol,
        "conservation violated: produced {produced} != consumed {consumed} + backlog {backlog}"
    );
    assert!(committed <= consumed + tol, "committed {committed} > consumed {consumed}");
    assert!(consumed <= produced + tol, "consumed {consumed} > produced {produced}");
    assert!(backlog >= -tol && sim.total_lag() >= -tol);
}

/// Sum of the recorded throughput series across workers (tuples).
fn throughput_integral(sim: &Simulation, upto: u64) -> f64 {
    let db = sim.tsdb();
    let mut total = 0.0;
    for w in 0..sim.max_replicas() {
        let id = SeriesId::worker("worker_throughput", w);
        total += db.fold_over(&id, 0, upto, 0.0, |acc, _, v| acc + v);
    }
    total
}

#[test]
fn conservation_under_random_rescale_and_failure_storms() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0xC0_5E7A);
        let shape = ShapeKind::all()[seed as usize % ShapeKind::all().len()];
        let duration = 2_400;
        // 0–3 random failure injections, sorted.
        let mut failures: Vec<u64> = (0..rng.below(4))
            .map(|_| 300 + rng.below(duration - 600))
            .collect();
        failures.sort_unstable();
        failures.dedup();
        let cfg = SimConfig {
            partitions: 36,
            initial_replicas: 1 + rng.below(12) as usize,
            seed,
            rate_noise: 0.02,
            failures,
            ..SimConfig::base(
                if seed % 2 == 0 {
                    EngineProfile::flink()
                } else {
                    EngineProfile::kstreams()
                },
                JobProfile::wordcount(),
                shape.build(25_000.0, duration, seed),
            )
        };
        let mut sim = Simulation::new(cfg);
        for t in 0..duration {
            sim.step(t);
            // Random rescale storm, ~1 request per 80 s; most requests mid
            // restart are ignored, which is part of what we exercise.
            if rng.below(80) == 0 {
                sim.request_rescale(1 + rng.below(12) as usize);
            }
            if t % 200 == 0 {
                assert_conservation(&sim);
            }
        }
        assert_conservation(&sim);

        // Latency-weight conservation: every processed tuple contributed
        // exactly its volume to the pooled latency samples AND to the
        // throughput series (replayed tuples appear in both, so the two
        // integrals match even across restarts).
        let weight = sim.latencies().total_weight();
        let tput = throughput_integral(&sim, duration);
        let rel = (weight - tput).abs() / tput.max(1.0);
        assert!(
            rel < 1e-6,
            "seed {seed} ({}): latency weight {weight} vs throughput integral {tput}",
            shape.name()
        );

        // Checkpoint completion reconciles the committed offset exactly.
        let mut t = duration;
        while !sim.ready() {
            sim.step(t);
            t += 1;
            assert!(t < duration + 600, "restart never completed");
        }
        sim.checkpoint_now();
        let tol = 1e-6 * sim.total_produced().max(1.0);
        assert!(
            (sim.total_committed() - sim.total_consumed()).abs() < tol,
            "checkpoint did not commit all consumption"
        );
        assert!(
            (sim.total_lag() - sim.total_backlog()).abs() < tol,
            "lag {} != backlog {} after checkpoint",
            sim.total_lag(),
            sim.total_backlog()
        );
    }
}

/// The heap-based FIFO merge must be *bit-identical* to the retained naive
/// reference scan: same consumed totals, same pooled latency histogram,
/// same TSDB contents, same rescale log — across randomized workload
/// shapes, rescale storms and failure injections. The `(head_time,
/// partition_idx)` heap tie-break is what makes this hold exactly.
#[test]
fn heap_merge_bit_identical_to_naive_reference_scan() {
    for seed in 0..4u64 {
        let shape = ShapeKind::all()[seed as usize % ShapeKind::all().len()];
        let duration = 1_500;
        let mut frng = Rng::new(seed ^ 0xFA_17);
        let mut failures: Vec<u64> = (0..frng.below(3))
            .map(|_| 300 + frng.below(duration - 600))
            .collect();
        failures.sort_unstable();
        failures.dedup();
        let build = |failures: &[u64]| {
            Simulation::new(SimConfig {
                partitions: 36,
                initial_replicas: 1 + (seed as usize % 8),
                seed,
                rate_noise: 0.02,
                failures: failures.to_vec(),
                ..SimConfig::base(
                    EngineProfile::flink(),
                    JobProfile::wordcount(),
                    shape.build(25_000.0, duration, seed),
                )
            })
        };
        let mut heap_sim = build(&failures);
        let mut naive_sim = build(&failures);
        naive_sim.set_merge_policy(MergePolicy::NaiveScan);
        // Identical rescale storms driven by twin PRNGs.
        let mut rng_a = Rng::new(seed ^ 0xAB);
        let mut rng_b = Rng::new(seed ^ 0xAB);
        for t in 0..duration {
            heap_sim.step(t);
            naive_sim.step(t);
            if rng_a.below(90) == 0 {
                heap_sim.request_rescale(1 + rng_a.below(12) as usize);
            }
            if rng_b.below(90) == 0 {
                naive_sim.request_rescale(1 + rng_b.below(12) as usize);
            }
        }
        let tag = format!("seed {seed} ({})", shape.name());
        assert_eq!(heap_sim.rescale_log, naive_sim.rescale_log, "{tag}: rescale logs diverged");
        assert_eq!(
            heap_sim.latencies(),
            naive_sim.latencies(),
            "{tag}: pooled latency histograms diverged"
        );
        assert_eq!(
            heap_sim.total_consumed().to_bits(),
            naive_sim.total_consumed().to_bits(),
            "{tag}: consumed totals diverged"
        );
        assert_eq!(
            heap_sim.total_backlog().to_bits(),
            naive_sim.total_backlog().to_bits(),
            "{tag}: backlogs diverged"
        );
        assert!(
            heap_sim.tsdb() == naive_sim.tsdb(),
            "{tag}: recorded metric series diverged"
        );
        assert_conservation(&heap_sim);
        assert_conservation(&naive_sim);
    }
}

/// Per-stage flow conservation of the staged engine: for every stage,
/// `tuples_out == tuples_in × selectivity` (within fp tolerance; drifting
/// operators are bounded by their drift endpoints instead), upstream
/// emissions equal downstream intake plus queued in-flight data, and the
/// source stage's intake equals the partitions' consumed offsets — all
/// checked under rescale storms, failure injection, and replay.
fn assert_operator_conservation(sim: &Simulation, topo: &Topology, drift_op: Option<usize>) {
    // Queue mass, upstream/downstream flow, and source-offset agreement.
    sim.check_invariants();
    for s in 0..sim.n_stages() {
        let flow = sim.stage_flow(s);
        let sel = topo.operators[s].selectivity;
        let tol = 1e-6 * flow.consumed.max(1.0);
        if Some(s) == drift_op {
            // The drifting operator's instantaneous selectivity moves
            // between its base and its drift target, so its integral only
            // admits envelope bounds — the flow checks in
            // `check_invariants` still pin it against its downstream.
            continue;
        }
        assert!(
            (flow.emitted - flow.consumed * sel).abs() < tol.max(1e-4),
            "stage {s}: emitted {} != consumed {} x selectivity {sel}",
            flow.emitted,
            flow.consumed
        );
        assert!(
            flow.committed_emitted <= flow.emitted + tol,
            "stage {s}: committed_emitted ran ahead of emitted"
        );
    }
}

#[test]
fn operator_conservation() {
    // Randomized over the registry's staged scenarios × 3 seeds, with a
    // mid-run failure and a seeded per-stage rescale storm on top (replay
    // and backfill included).
    let duration = 1_500u64;
    let reg = ScenarioRegistry::builtin(duration, &[1, 2, 3]);
    for name in [
        "flink-wordcount-bottleneck-shift",
        "flink-ysb-bottleneck-shift",
        "flink-wordcount-skew-amplify",
        "kstreams-ysb-skew-amplify",
    ] {
        let sc = reg.get(name).expect("staged scenario registered");
        assert_eq!(sc.stage_model, StageModel::Staged, "{name}");
        let topo = sc.job.profile().topology();
        let drift_op = sc.selectivity_drift.map(|d| d.op);
        for &seed in &sc.seeds {
            let mut sim = Simulation::new(SimConfig {
                partitions: sc.partitions,
                initial_replicas: sc.initial_replicas,
                max_replicas: sc.max_replicas,
                seed,
                rate_noise: 0.02,
                failures: vec![duration / 2],
                stage_model: sc.stage_model,
                selectivity_drift: sc.selectivity_drift,
                zipf_override: sc.zipf_override,
                ..SimConfig::base(sc.engine.profile(), sc.job.profile(), sc.workload(seed))
            });
            assert_eq!(sim.n_stages(), topo.operators.len());
            let mut rng = Rng::new(seed ^ 0x57A6ED);
            for t in 0..duration {
                sim.step(t);
                if rng.below(130) == 0 {
                    let v: Vec<usize> = (0..sim.n_stages())
                        .map(|_| 1 + rng.below(8) as usize)
                        .collect();
                    sim.request_rescale_stages(&v);
                }
                if t % 300 == 0 {
                    assert_operator_conservation(&sim, &topo, drift_op);
                }
            }
            assert_operator_conservation(&sim, &topo, drift_op);
            // The pipeline actually processed traffic end to end.
            assert!(
                sim.latencies().total_weight() > 0.0,
                "{name} seed {seed}: sink stage saw no tuples"
            );
            let last = sim.stage_flow(sim.n_stages() - 1);
            assert!(last.consumed > 0.0);
        }
    }
}

/// The bucket-ring inter-stage queues must agree with the retained
/// chunk-list reference (`QueuePolicy::Chunked` — PR-3's exact
/// representation, bit for bit) on every staged scenario in the registry,
/// through per-stage rescale storms, a failure injection, and the
/// checkpoint/replay machinery they trigger.
///
/// The pin is quantization-identity, not bit-identity: the ring coalesces
/// *all* equal-tick mass into one bucket while the chunk list sorts the
/// source-replica merge and coalesces in sorted order, so float additions
/// regroup — the same sub-ulp effect PR 2 documented for same-timestamp
/// chunk coalescing, absorbed by the 1/1000 golden-trace quantization.
/// Restart timelines (times, totals, downtime draws) must still match
/// *exactly*: RNG draw order is content-independent, so any divergence
/// there would mean the policies disagree structurally, not numerically.
#[test]
fn bucket_ring_agrees_with_chunked_reference_on_all_staged_scenarios() {
    let duration = 1_200u64;
    let reg = ScenarioRegistry::builtin(duration, &[1]);
    for name in [
        "flink-wordcount-bottleneck-shift",
        "flink-ysb-bottleneck-shift",
        "flink-wordcount-skew-amplify",
        "kstreams-ysb-skew-amplify",
        "flink-wordcount-diurnal-week",
        "kstreams-ysb-diurnal-week",
    ] {
        let sc = reg.get(name).expect("staged scenario registered");
        assert_eq!(sc.stage_model, StageModel::Staged, "{name}");
        for &seed in &sc.seeds {
            let build = || {
                Simulation::new(SimConfig {
                    partitions: sc.partitions,
                    initial_replicas: sc.initial_replicas,
                    max_replicas: sc.max_replicas,
                    seed,
                    rate_noise: 0.02,
                    failures: vec![duration / 2],
                    stage_model: sc.stage_model,
                    selectivity_drift: sc.selectivity_drift,
                    zipf_override: sc.zipf_override,
                    ..SimConfig::base(sc.engine.profile(), sc.job.profile(), sc.workload(seed))
                })
            };
            let mut ring = build();
            let mut chunked = build();
            assert_eq!(ring.queue_policy(), QueuePolicy::BucketRing);
            chunked.set_queue_policy(QueuePolicy::Chunked);
            // Identical per-stage rescale storms driven by twin PRNGs.
            let mut rng_a = Rng::new(seed ^ 0xB0C4E7);
            let mut rng_b = Rng::new(seed ^ 0xB0C4E7);
            let mut storm = |rng: &mut Rng, sim: &mut Simulation| {
                if rng.below(130) == 0 {
                    let v: Vec<usize> = (0..sim.n_stages())
                        .map(|_| 1 + rng.below(8) as usize)
                        .collect();
                    sim.request_rescale_stages(&v);
                }
            };
            for t in 0..duration {
                ring.step(t);
                chunked.step(t);
                storm(&mut rng_a, &mut ring);
                storm(&mut rng_b, &mut chunked);
            }
            let tag = format!("{name} seed {seed}");
            assert_eq!(ring.rescale_log, chunked.rescale_log, "{tag}: restart timelines diverged");
            let close = |a: f64, b: f64, what: &str| {
                let tol = (1e-6 * a.abs().max(1.0)).max(1.0);
                assert!(
                    (a - b).abs() < tol,
                    "{tag}: {what} diverged beyond regrouping tolerance: ring {a} vs chunked {b}"
                );
            };
            close(ring.total_produced(), chunked.total_produced(), "produced");
            close(ring.total_consumed(), chunked.total_consumed(), "consumed");
            close(ring.total_backlog(), chunked.total_backlog(), "backlog");
            close(
                ring.latencies().total_weight(),
                chunked.latencies().total_weight(),
                "latency weight",
            );
            for s in 0..ring.n_stages() {
                let a = ring.stage_flow(s);
                let b = chunked.stage_flow(s);
                close(a.consumed, b.consumed, &format!("stage {s} consumed"));
                close(a.emitted, b.emitted, &format!("stage {s} emitted"));
                close(a.queue_backlog, b.queue_backlog, &format!("stage {s} queue"));
            }
            // Per-stage flow conservation holds under both policies (the
            // job-level `assert_conservation` does not apply: staged
            // `total_backlog` includes in-flight inter-stage mass).
            ring.check_invariants();
            chunked.check_invariants();
            // Both pipelines actually processed traffic end to end.
            assert!(ring.latencies().total_weight() > 0.0, "{tag}: sink saw no tuples");
        }
    }
}

/// The staged engine collapses to the fused flat pool on single-operator
/// topologies: same FIFO merge, same replica capacities, same restart
/// semantics. Totals must agree to fp tolerance (the only difference is
/// the `1e6/cost` round-trip on the per-replica capacity) across rescale
/// storms and a failure injection.
#[test]
fn staged_and_fused_agree_on_single_operator_topologies() {
    for seed in 0..3u64 {
        let job = JobProfile::wordcount();
        let topo = Topology::single("flat", job.base_capacity);
        let build = |model: StageModel| {
            Simulation::new(SimConfig {
                partitions: 36,
                seed,
                rate_noise: 0.02,
                failures: vec![600],
                stage_model: model,
                topology: Some(topo.clone()),
                ..SimConfig::base(
                    EngineProfile::flink(),
                    job.clone(),
                    ShapeKind::Sine.build(20_000.0, 1_200, seed),
                )
            })
        };
        let mut fused = build(StageModel::Fused);
        let mut staged = build(StageModel::Staged);
        let mut rng_a = Rng::new(seed ^ 0xF0_5ED);
        let mut rng_b = Rng::new(seed ^ 0xF0_5ED);
        for t in 0..1_200 {
            fused.step(t);
            staged.step(t);
            if rng_a.below(150) == 0 {
                fused.request_rescale(1 + rng_a.below(10) as usize);
            }
            if rng_b.below(150) == 0 {
                staged.request_rescale(1 + rng_b.below(10) as usize);
            }
        }
        assert_eq!(
            fused.rescale_log, staged.rescale_log,
            "seed {seed}: restart timelines diverged"
        );
        let close = |a: f64, b: f64, what: &str| {
            let tol = 1e-9 * a.abs().max(1.0);
            assert!(
                (a - b).abs() < tol.max(1e-6),
                "seed {seed}: {what} diverged: fused {a} vs staged {b}"
            );
        };
        close(fused.total_produced(), staged.total_produced(), "produced");
        close(fused.total_consumed(), staged.total_consumed(), "consumed");
        close(fused.total_committed(), staged.total_committed(), "committed");
        close(fused.total_backlog(), staged.total_backlog(), "backlog");
        close(
            fused.worker_seconds(),
            staged.worker_seconds(),
            "worker-seconds",
        );
        fused.check_invariants();
        staged.check_invariants();
    }
}

/// Every typed fault class, on both stage models, driven per-tick and
/// through `advance_quiet`: the two drivers must agree *bitwise* (all
/// fault effects live in `begin_tick`, which both drivers run for every
/// tick; the `next_boundary` hooks are purely advisory), flow must stay
/// conserved through the injected restarts/replays, and each class must
/// exhibit its defining restart signature (gray failures never restart,
/// crash loops retry under backoff, everything else restarts exactly once).
#[test]
fn conservation_and_mode_agreement_under_every_typed_fault() {
    let timelines: Vec<(&str, FaultTimeline)> = vec![
        (
            "worker-crash",
            FaultTimeline::new(vec![FaultEvent::WorkerCrash { t: 200, k: 2 }]),
        ),
        (
            "zone-outage",
            FaultTimeline::new(vec![FaultEvent::ZoneOutage {
                t: 200,
                fraction: 0.5,
            }]),
        ),
        (
            "gray-failure",
            FaultTimeline::new(vec![FaultEvent::GrayFailure {
                from: 150,
                to: 400,
                worker: 1,
                severity: 0.5,
            }]),
        ),
        (
            "crash-loop",
            FaultTimeline::new(vec![FaultEvent::CrashLoop {
                t: 200,
                fail_prob: 0.999,
                max_retries: 3,
            }]),
        ),
        (
            "checkpoint-loss",
            FaultTimeline::new(vec![FaultEvent::CheckpointLoss { t: 250 }]),
        ),
    ];
    let duration = 900u64;
    for (tag, tl) in &timelines {
        for staged in [false, true] {
            let build = || {
                Simulation::new(SimConfig {
                    partitions: 24,
                    initial_replicas: if staged { 2 } else { 4 },
                    seed: 41,
                    rate_noise: 0.02,
                    faults: tl.clone(),
                    stage_model: if staged {
                        StageModel::Staged
                    } else {
                        StageModel::Fused
                    },
                    ..SimConfig::base(
                        EngineProfile::flink(),
                        JobProfile::wordcount(),
                        ShapeKind::Sine.build(12_000.0, duration, 41),
                    )
                })
            };
            let mut per_tick = build();
            let mut event = build();
            for t in 0..duration {
                per_tick.step(t);
            }
            event.advance_quiet(0, duration);
            let what = format!("{tag} staged={staged}");
            assert_eq!(per_tick.latencies(), event.latencies(), "{what}: latencies");
            assert!(per_tick.tsdb() == event.tsdb(), "{what}: tsdb diverged");
            assert_eq!(
                per_tick.total_consumed().to_bits(),
                event.total_consumed().to_bits(),
                "{what}: consumed"
            );
            assert_eq!(
                per_tick.total_backlog().to_bits(),
                event.total_backlog().to_bits(),
                "{what}: backlog"
            );
            assert_eq!(per_tick.rescale_log, event.rescale_log, "{what}: restarts");
            assert_eq!(
                per_tick.restart_retries(),
                event.restart_retries(),
                "{what}: retries"
            );
            assert_eq!(per_tick.down_ticks(), event.down_ticks(), "{what}: down ticks");

            // Conservation after the dust settles. The job-level identity
            // `produced == consumed + backlog` only applies to the fused
            // pool (staged backlog includes inter-stage mass in per-stage
            // input units); the staged pipeline pins per-stage flow.
            if staged {
                let topo = JobProfile::wordcount().topology();
                assert_operator_conservation(&per_tick, &topo, None);
            } else {
                assert_conservation(&per_tick);
            }

            // Restart signature per fault class.
            let restarts = per_tick.rescale_log.iter().filter(|e| e.failure).count();
            if *tag == "gray-failure" {
                assert_eq!(restarts, 0, "{what}: gray failures never restart");
            } else {
                assert_eq!(restarts, 1, "{what}: one fault, one logged restart");
            }
            if *tag == "crash-loop" {
                assert!(
                    per_tick.restart_retries() <= 3,
                    "{what}: retries exceeded the budget"
                );
                assert!(per_tick.down_ticks() > 0, "{what}: no downtime observed");
            } else {
                assert_eq!(per_tick.restart_retries(), 0, "{what}: spurious retries");
            }
            assert!(
                per_tick.latencies().total_weight() > 0.0,
                "{what}: no tuples processed"
            );
        }
    }
}

/// Every reconfiguration path — checkpoint-interval change, queue-bound
/// grow, queue-bound shrink (mid-backlog: clamps to current occupancy and
/// throttles upstream, never drops in-flight mass), backpressure change —
/// on both stage models, driven per-tick and through `advance_quiet`:
/// the two drivers must agree *bitwise* (configs apply at the next
/// consistent cut, which both drivers reach through `begin_tick`/
/// `complete_checkpoint`; `next_reconfigure_boundary` is purely
/// advisory), flow must stay conserved through the applied change, and
/// every request must land in the `reconfigure_log` exactly once with
/// the consistent-cut semantics (`t >= requested_at`, applied config
/// matches the request).
#[test]
fn conservation_and_mode_agreement_under_reconfiguration() {
    use daedalus::dsp::RuntimeConfig;

    let configs: Vec<(&str, RuntimeConfig)> = vec![
        (
            "checkpoint-interval",
            RuntimeConfig {
                checkpoint_interval: 4,
                backpressure_secs: 5.0,
                queue_bound_secs: Vec::new(),
            },
        ),
        (
            "queue-bound-grow",
            RuntimeConfig {
                checkpoint_interval: 10,
                backpressure_secs: 5.0,
                queue_bound_secs: vec![0.0, 20.0, 20.0],
            },
        ),
        (
            "queue-bound-shrink",
            RuntimeConfig {
                checkpoint_interval: 10,
                backpressure_secs: 5.0,
                queue_bound_secs: vec![0.0, 0.5, 0.5],
            },
        ),
        (
            "backpressure",
            RuntimeConfig {
                checkpoint_interval: 10,
                backpressure_secs: 1.5,
                queue_bound_secs: Vec::new(),
            },
        ),
    ];
    let duration = 900u64;
    for (tag, config) in &configs {
        for staged in [false, true] {
            let build = || {
                Simulation::new(SimConfig {
                    partitions: 24,
                    // Underprovisioned on the staged pipeline so the
                    // inter-stage queues carry real mass when the shrink
                    // lands mid-backlog.
                    initial_replicas: if staged { 2 } else { 4 },
                    seed: 47,
                    rate_noise: 0.02,
                    stage_model: if staged {
                        StageModel::Staged
                    } else {
                        StageModel::Fused
                    },
                    ..SimConfig::base(
                        EngineProfile::flink(),
                        JobProfile::wordcount(),
                        ShapeKind::Sine.build(14_000.0, duration, 47),
                    )
                })
            };
            let mut per_tick = build();
            let mut event = build();
            for t in 0..duration {
                per_tick.step(t);
                if t == 299 {
                    assert!(per_tick.request_reconfigure(config.clone()), "{tag}");
                }
            }
            event.advance_quiet(0, 300);
            assert!(event.request_reconfigure(config.clone()), "{tag}");
            event.advance_quiet(300, duration);
            let what = format!("{tag} staged={staged}");
            assert_eq!(per_tick.latencies(), event.latencies(), "{what}: latencies");
            assert!(per_tick.tsdb() == event.tsdb(), "{what}: tsdb diverged");
            assert_eq!(
                per_tick.total_consumed().to_bits(),
                event.total_consumed().to_bits(),
                "{what}: consumed"
            );
            assert_eq!(
                per_tick.total_backlog().to_bits(),
                event.total_backlog().to_bits(),
                "{what}: backlog"
            );
            assert_eq!(
                per_tick.worker_seconds().to_bits(),
                event.worker_seconds().to_bits(),
                "{what}: worker-seconds"
            );
            assert_eq!(
                per_tick.reconfigure_log, event.reconfigure_log,
                "{what}: reconfigure log"
            );

            // Consistent-cut semantics: the request landed exactly once,
            // at or after the request tick, with the requested config.
            assert_eq!(per_tick.reconfigure_log.len(), 1, "{what}: applications");
            let ev = &per_tick.reconfigure_log[0];
            assert_eq!(ev.requested_at, 299, "{what}: request tick");
            assert!(ev.t >= 299, "{what}: applied before the request");
            assert_eq!(&ev.config, config, "{what}: applied config");
            assert_eq!(per_tick.runtime_config(), config, "{what}: active config");
            assert!(per_tick.pending_reconfigure().is_none(), "{what}: still pending");

            // Flow conservation with the new configuration active — the
            // shrink path in particular must not have dropped in-flight
            // queue mass.
            if staged {
                let topo = JobProfile::wordcount().topology();
                assert_operator_conservation(&per_tick, &topo, None);
            } else {
                assert_conservation(&per_tick);
            }
            assert!(
                per_tick.latencies().total_weight() > 0.0,
                "{what}: no tuples processed"
            );
        }
    }
}

/// Every telemetry fault class, on both stage models, driven per-tick and
/// through `advance_quiet`: telemetry faults live entirely on the
/// autoscaler-facing read path (the [`daedalus::dsp::TelemetryLens`]) and
/// the rescale API, so the two drivers must stay *bitwise* identical —
/// engine bookkeeping is untouched by construction — and flow must stay
/// conserved. A mid-run rescale request inside each fault window
/// exercises the actuator-denial accounting identically under both
/// drivers: `ActuatorFault` denies it (counted in `dropped_rescales`,
/// nothing logged), every read-path class lets it through.
#[test]
fn conservation_and_mode_agreement_under_every_telemetry_fault() {
    let timelines: Vec<(&str, TelemetryFaultTimeline)> = vec![
        (
            "metric-dropout",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricDropout {
                from: 200,
                to: 400,
            }]),
        ),
        (
            "metric-staleness",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricStaleness {
                from: 200,
                to: 400,
                delay: 120,
            }]),
        ),
        (
            "corruption-spike",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricCorruption {
                from: 200,
                to: 400,
                pattern: SeriesPattern::WorkerSeries("worker_throughput"),
                kind: CorruptionKind::Spike { factor: 6.0 },
                seed: 0x5EED,
            }]),
        ),
        (
            "corruption-freeze",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricCorruption {
                from: 200,
                to: 400,
                pattern: SeriesPattern::WorkerSeries("worker_cpu"),
                kind: CorruptionKind::Freeze,
                seed: 0x0F0F,
            }]),
        ),
        (
            "corruption-nan",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricCorruption {
                from: 200,
                to: 400,
                pattern: SeriesPattern::WorkerSeries("worker_cpu"),
                kind: CorruptionKind::Nan,
                seed: 0x0BAD,
            }]),
        ),
        (
            "actuator-fault",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::ActuatorFault {
                from: 250,
                to: 500,
            }]),
        ),
    ];
    let duration = 900u64;
    for (tag, tl) in &timelines {
        for staged in [false, true] {
            let build = || {
                Simulation::new(SimConfig {
                    partitions: 24,
                    initial_replicas: if staged { 2 } else { 4 },
                    seed: 43,
                    rate_noise: 0.02,
                    telemetry: tl.clone(),
                    stage_model: if staged {
                        StageModel::Staged
                    } else {
                        StageModel::Fused
                    },
                    ..SimConfig::base(
                        EngineProfile::flink(),
                        JobProfile::wordcount(),
                        ShapeKind::Sine.build(12_000.0, duration, 43),
                    )
                })
            };
            let request = |sim: &mut Simulation| {
                if staged {
                    let v = vec![3usize; sim.n_stages()];
                    sim.request_rescale_stages(&v);
                } else {
                    sim.request_rescale(6);
                }
            };
            let mut per_tick = build();
            let mut event = build();
            for t in 0..duration {
                per_tick.step(t);
                if t == 299 {
                    request(&mut per_tick);
                }
            }
            event.advance_quiet(0, 300);
            request(&mut event);
            event.advance_quiet(300, duration);
            let what = format!("{tag} staged={staged}");
            assert_eq!(per_tick.latencies(), event.latencies(), "{what}: latencies");
            assert!(per_tick.tsdb() == event.tsdb(), "{what}: tsdb diverged");
            assert_eq!(
                per_tick.total_consumed().to_bits(),
                event.total_consumed().to_bits(),
                "{what}: consumed"
            );
            assert_eq!(
                per_tick.total_backlog().to_bits(),
                event.total_backlog().to_bits(),
                "{what}: backlog"
            );
            assert_eq!(
                per_tick.worker_seconds().to_bits(),
                event.worker_seconds().to_bits(),
                "{what}: worker-seconds"
            );
            assert_eq!(per_tick.rescale_log, event.rescale_log, "{what}: rescale log");
            assert_eq!(
                per_tick.dropped_rescales(),
                event.dropped_rescales(),
                "{what}: dropped rescales"
            );

            // Flow conservation with the fault plane active.
            if staged {
                let topo = JobProfile::wordcount().topology();
                assert_operator_conservation(&per_tick, &topo, None);
            } else {
                assert_conservation(&per_tick);
            }

            // Per-class actuation signature: only the dead rescale API
            // swallows the request.
            if *tag == "actuator-fault" {
                assert!(
                    per_tick.dropped_rescales() >= 1,
                    "{what}: denial window did not count the request"
                );
                assert!(
                    per_tick.rescale_log.is_empty(),
                    "{what}: a denied rescale was logged"
                );
            } else {
                assert_eq!(per_tick.dropped_rescales(), 0, "{what}: spurious denial");
                assert_eq!(per_tick.rescale_log.len(), 1, "{what}: rescale not applied");
            }
            assert!(
                per_tick.latencies().total_weight() > 0.0,
                "{what}: no tuples processed"
            );
        }
    }
}

#[test]
fn drained_system_conserves_everything_exactly() {
    // Constant load, then the workload stops (shape ends): after the queue
    // drains, consumed == produced and backlog == 0.
    let cfg = SimConfig {
        partitions: 24,
        initial_replicas: 6,
        seed: 3,
        failures: vec![600],
        ..SimConfig::base(
            EngineProfile::flink(),
            JobProfile::wordcount(),
            ShapeKind::Sine.build(15_000.0, 1_200, 3),
        )
    };
    let mut sim = Simulation::new(cfg);
    for t in 0..1_200 {
        sim.step(t);
    }
    // Past the trace end the sine shape keeps emitting its t-dependent
    // rate; drain by consuming faster than the peak can arrive: rescale to
    // max and give it time.
    sim.request_rescale(12);
    for t in 1_200..2_400 {
        sim.step(t);
    }
    assert_conservation(&sim);
    assert!(sim.ready());
    assert!(
        sim.total_backlog() < 1_000.0,
        "backlog {} did not drain",
        sim.total_backlog()
    );
}

#[test]
fn conservation_holds_for_every_workload_shape_with_autoscaling() {
    use daedalus::autoscaler::{Autoscaler, Daedalus, DaedalusConfig};
    use daedalus::runtime::ComputeBackend;

    for shape in ShapeKind::all() {
        let cfg = SimConfig {
            partitions: 36,
            seed: 11,
            rate_noise: 0.02,
            failures: vec![900],
            ..SimConfig::base(
                EngineProfile::flink(),
                JobProfile::wordcount(),
                shape.build(25_000.0, 2_000, 11),
            )
        };
        let mut sim = Simulation::new(cfg);
        let mut d = Daedalus::new(DaedalusConfig::default(), ComputeBackend::native());
        for t in 0..2_000 {
            sim.step(t);
            if let Some(n) = d.decide(&sim.view()) {
                sim.request_rescale(n);
            }
        }
        assert_conservation(&sim);
        assert!(
            sim.latencies().total_weight() > 0.0,
            "{}: no tuples processed",
            shape.name()
        );
    }
}
