//! Conservation invariants of the DSP substrate under adversarial
//! schedules — hand-rolled property-style tests (proptest is unavailable
//! offline): seeded random rescale/failure storms across every workload
//! shape, checking after every phase that no tuple is lost or invented.
//!
//! Invariants:
//! 1. `produced == consumed + backlog` (offset bookkeeping, exact).
//! 2. `committed ≤ consumed ≤ produced` (exactly-once ordering).
//! 3. After a completed checkpoint, `committed == consumed` and the
//!    Kafka-visible lag equals the backlog.
//! 4. Every latency sample's weight comes from a consumed chunk: pooled
//!    latency weight equals the integral of recorded throughput.
//! 5. Queue mass equals backlog per partition (`check_invariants`).

use daedalus::dsp::{EngineProfile, MergePolicy, SimConfig, Simulation};
use daedalus::jobs::JobProfile;
use daedalus::metrics::SeriesId;
use daedalus::stats::Rng;
use daedalus::workload::ShapeKind;

fn assert_conservation(sim: &Simulation) {
    sim.check_invariants();
    let produced = sim.total_produced();
    let consumed = sim.total_consumed();
    let committed = sim.total_committed();
    let backlog = sim.total_backlog();
    let tol = 1e-6 * produced.max(1.0);
    assert!(
        (produced - consumed - backlog).abs() < tol,
        "conservation violated: produced {produced} != consumed {consumed} + backlog {backlog}"
    );
    assert!(committed <= consumed + tol, "committed {committed} > consumed {consumed}");
    assert!(consumed <= produced + tol, "consumed {consumed} > produced {produced}");
    assert!(backlog >= -tol && sim.total_lag() >= -tol);
}

/// Sum of the recorded throughput series across workers (tuples).
fn throughput_integral(sim: &Simulation, upto: u64) -> f64 {
    let db = sim.tsdb();
    let mut total = 0.0;
    for w in 0..sim.max_replicas() {
        let id = SeriesId::worker("worker_throughput", w);
        total += db.fold_over(&id, 0, upto, 0.0, |acc, _, v| acc + v);
    }
    total
}

#[test]
fn conservation_under_random_rescale_and_failure_storms() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0xC0_5E7A);
        let shape = ShapeKind::all()[seed as usize % 6];
        let duration = 2_400;
        // 0–3 random failure injections, sorted.
        let mut failures: Vec<u64> = (0..rng.below(4))
            .map(|_| 300 + rng.below(duration - 600))
            .collect();
        failures.sort_unstable();
        failures.dedup();
        let cfg = SimConfig {
            profile: if seed % 2 == 0 {
                EngineProfile::flink()
            } else {
                EngineProfile::kstreams()
            },
            job: JobProfile::wordcount(),
            workload: shape.build(25_000.0, duration, seed),
            partitions: 36,
            initial_replicas: 1 + rng.below(12) as usize,
            max_replicas: 12,
            seed,
            rate_noise: 0.02,
            failures,
        };
        let mut sim = Simulation::new(cfg);
        for t in 0..duration {
            sim.step(t);
            // Random rescale storm, ~1 request per 80 s; most requests mid
            // restart are ignored, which is part of what we exercise.
            if rng.below(80) == 0 {
                sim.request_rescale(1 + rng.below(12) as usize);
            }
            if t % 200 == 0 {
                assert_conservation(&sim);
            }
        }
        assert_conservation(&sim);

        // Latency-weight conservation: every processed tuple contributed
        // exactly its volume to the pooled latency samples AND to the
        // throughput series (replayed tuples appear in both, so the two
        // integrals match even across restarts).
        let weight = sim.latencies().total_weight();
        let tput = throughput_integral(&sim, duration);
        let rel = (weight - tput).abs() / tput.max(1.0);
        assert!(
            rel < 1e-6,
            "seed {seed} ({}): latency weight {weight} vs throughput integral {tput}",
            shape.name()
        );

        // Checkpoint completion reconciles the committed offset exactly.
        let mut t = duration;
        while !sim.ready() {
            sim.step(t);
            t += 1;
            assert!(t < duration + 600, "restart never completed");
        }
        sim.checkpoint_now();
        let tol = 1e-6 * sim.total_produced().max(1.0);
        assert!(
            (sim.total_committed() - sim.total_consumed()).abs() < tol,
            "checkpoint did not commit all consumption"
        );
        assert!(
            (sim.total_lag() - sim.total_backlog()).abs() < tol,
            "lag {} != backlog {} after checkpoint",
            sim.total_lag(),
            sim.total_backlog()
        );
    }
}

/// The heap-based FIFO merge must be *bit-identical* to the retained naive
/// reference scan: same consumed totals, same pooled latency histogram,
/// same TSDB contents, same rescale log — across randomized workload
/// shapes, rescale storms and failure injections. The `(head_time,
/// partition_idx)` heap tie-break is what makes this hold exactly.
#[test]
fn heap_merge_bit_identical_to_naive_reference_scan() {
    for seed in 0..4u64 {
        let shape = ShapeKind::all()[seed as usize % 6];
        let duration = 1_500;
        let mut frng = Rng::new(seed ^ 0xFA_17);
        let mut failures: Vec<u64> = (0..frng.below(3))
            .map(|_| 300 + frng.below(duration - 600))
            .collect();
        failures.sort_unstable();
        failures.dedup();
        let build = |failures: &[u64]| {
            Simulation::new(SimConfig {
                profile: EngineProfile::flink(),
                job: JobProfile::wordcount(),
                workload: shape.build(25_000.0, duration, seed),
                partitions: 36,
                initial_replicas: 1 + (seed as usize % 8),
                max_replicas: 12,
                seed,
                rate_noise: 0.02,
                failures: failures.to_vec(),
            })
        };
        let mut heap_sim = build(&failures);
        let mut naive_sim = build(&failures);
        naive_sim.set_merge_policy(MergePolicy::NaiveScan);
        // Identical rescale storms driven by twin PRNGs.
        let mut rng_a = Rng::new(seed ^ 0xAB);
        let mut rng_b = Rng::new(seed ^ 0xAB);
        for t in 0..duration {
            heap_sim.step(t);
            naive_sim.step(t);
            if rng_a.below(90) == 0 {
                heap_sim.request_rescale(1 + rng_a.below(12) as usize);
            }
            if rng_b.below(90) == 0 {
                naive_sim.request_rescale(1 + rng_b.below(12) as usize);
            }
        }
        let tag = format!("seed {seed} ({})", shape.name());
        assert_eq!(heap_sim.rescale_log, naive_sim.rescale_log, "{tag}: rescale logs diverged");
        assert_eq!(
            heap_sim.latencies(),
            naive_sim.latencies(),
            "{tag}: pooled latency histograms diverged"
        );
        assert_eq!(
            heap_sim.total_consumed().to_bits(),
            naive_sim.total_consumed().to_bits(),
            "{tag}: consumed totals diverged"
        );
        assert_eq!(
            heap_sim.total_backlog().to_bits(),
            naive_sim.total_backlog().to_bits(),
            "{tag}: backlogs diverged"
        );
        assert!(
            heap_sim.tsdb() == naive_sim.tsdb(),
            "{tag}: recorded metric series diverged"
        );
        assert_conservation(&heap_sim);
        assert_conservation(&naive_sim);
    }
}

#[test]
fn drained_system_conserves_everything_exactly() {
    // Constant load, then the workload stops (shape ends): after the queue
    // drains, consumed == produced and backlog == 0.
    let cfg = SimConfig {
        profile: EngineProfile::flink(),
        job: JobProfile::wordcount(),
        workload: ShapeKind::Sine.build(15_000.0, 1_200, 3),
        partitions: 24,
        initial_replicas: 6,
        max_replicas: 12,
        seed: 3,
        rate_noise: 0.0,
        failures: vec![600],
    };
    let mut sim = Simulation::new(cfg);
    for t in 0..1_200 {
        sim.step(t);
    }
    // Past the trace end the sine shape keeps emitting its t-dependent
    // rate; drain by consuming faster than the peak can arrive: rescale to
    // max and give it time.
    sim.request_rescale(12);
    for t in 1_200..2_400 {
        sim.step(t);
    }
    assert_conservation(&sim);
    assert!(sim.ready());
    assert!(
        sim.total_backlog() < 1_000.0,
        "backlog {} did not drain",
        sim.total_backlog()
    );
}

#[test]
fn conservation_holds_for_every_workload_shape_with_autoscaling() {
    use daedalus::autoscaler::{Autoscaler, Daedalus, DaedalusConfig};
    use daedalus::runtime::ComputeBackend;

    for shape in ShapeKind::all() {
        let cfg = SimConfig {
            profile: EngineProfile::flink(),
            job: JobProfile::wordcount(),
            workload: shape.build(25_000.0, 2_000, 11),
            partitions: 36,
            initial_replicas: 4,
            max_replicas: 12,
            seed: 11,
            rate_noise: 0.02,
            failures: vec![900],
        };
        let mut sim = Simulation::new(cfg);
        let mut d = Daedalus::new(DaedalusConfig::default(), ComputeBackend::native());
        for t in 0..2_000 {
            sim.step(t);
            if let Some(n) = d.decide(&sim.view()) {
                sim.request_rescale(n);
            }
        }
        assert_conservation(&sim);
        assert!(
            sim.latencies().total_weight() > 0.0,
            "{}: no tuples processed",
            shape.name()
        );
    }
}
