//! Golden-trace regression suite: one canonical scenario per autoscaler,
//! pinned by its deterministic trace digest — plus, since the
//! operator-stage refactor, one *staged-engine* golden per autoscaler on
//! the canonical `bottleneck-shift` scenario.
//!
//! ## Why the fused goldens did NOT need re-blessing (PR 3)
//!
//! The stage refactor left `StageModel::Fused` — the model every
//! pre-existing scenario runs on — bit-compatible: the per-tick serve
//! path, RNG draw order, and restart semantics are unchanged, and the
//! drift-aware capacity hook returns the exact configured constant when no
//! drift is set. Per the determinism contract (ROADMAP), a behavior change
//! would require `UPDATE_GOLDEN=1` + a PR note; none was needed. The new
//! `staged-*` goldens pin the staged engine's observable behavior from its
//! first release, so later changes to stage scheduling, backpressure
//! bounds, or per-stage planning must re-bless *those* deliberately.
//!
//! ## Staged goldens re-blessed for the bucket-ring queues (PR 4)
//!
//! The staged engine's inter-stage queues defaulted from the chunk list to
//! the bucket ring (`dsp::QueuePolicy::BucketRing`). The ring coalesces
//! *all* equal-tick mass into one per-tick bucket, where the chunk list
//! sorted the source-replica merge and coalesced in sorted order — float
//! additions regroup, a sub-ulp effect absorbed by the 1/1000 trace
//! quantization exactly as PR 2's same-timestamp chunk coalescing was
//! (`tests/invariants.rs::bucket_ring_agrees_with_chunked_reference_on_all_staged_scenarios`
//! pins the ring against the retained chunk list at that tolerance, with
//! restart timelines matching exactly). Values straddling a 1/1000
//! rounding boundary can still flip a digest bit, so the `staged-*`
//! goldens are re-blessed with this PR; the fused goldens are untouched
//! (the fused serve path does not use inter-stage queues, and the columnar
//! TSDB stores bit-identical samples). Digest files are not committed in
//! this repo — fresh checkouts self-bless — so the re-bless is this note
//! plus the property pin.
//!
//! ## Goldens re-blessed for the fault subsystem (PR 7)
//!
//! Two deliberate digest-layout/behavior changes ship with the typed
//! fault-injection subsystem (`dsp::faults`):
//!
//! 1. The trace digest grew a field: `RunTrace::dropped_rescales` (rescale
//!    plans refused mid-restart) is folded into the FNV stream after the
//!    event list, so *every* digest changes even where behavior did not.
//! 2. The harness SLO downtime term switched from summing the rescale
//!    log's *scheduled* downtime to the engine's actual `down_ticks`
//!    counter — the only term that can see crash-loop retry-backoff
//!    windows, which never appear in the rescale log. On restart-bearing
//!    cells the violated-seconds figure moves from a fractional schedule
//!    to the ceil'd tick count the deployment really spent down.
//!
//! Digest files are not committed (fresh checkouts self-bless), so the
//! re-bless is this note plus the mode-agreement pins: the event-driven /
//! per-tick bitwise contract now also covers every fault class
//! (`tests/invariants.rs::conservation_and_mode_agreement_under_every_typed_fault`
//! and the chaos cells in the registry-wide `tests/event_driven.rs` pin).
//!
//! ## Goldens re-blessed for the runtime-config subsystem (PR 10)
//!
//! The trace digest grew a section: the `reconfigure` event class
//! (`RunTrace::reconfigures` — runtime-config changes applied at
//! consistent cuts) is folded into the FNV stream between the event list
//! and `dropped_rescales`. The section's length word is written even when
//! empty, so *every* digest changes even where behavior did not — the
//! same deliberate layout policy as PR 7's `dropped_rescales` field.
//! Behavior itself is unchanged for every pre-existing approach: no
//! scale-out-only autoscaler issues reconfigure requests, and the engine
//! starts from `RuntimeConfig::from_profile`, bit-identical to the
//! pre-reconfigure knobs. Digest files are not committed (fresh checkouts
//! self-bless), so the re-bless is this note plus the reconfiguration
//! mode-agreement pin
//! (`tests/invariants.rs::conservation_and_mode_agreement_under_reconfiguration`).
//! The new `demeter-*` goldens pin the multi-config co-optimizer's
//! observable behavior — parallelism plans *and* applied configs — on its
//! two canonical cells from its first release.
//!
//! ## How the pinning works
//!
//! Each test runs its canonical `(scenario, approach, seed)` unit and
//! compares the trace digest against `tests/golden/<approach>.digest`.
//!
//! * If the golden file exists, the digests must match — any mismatch means
//!   autoscaler-observable behavior changed.
//! * If it does not exist yet (fresh checkout/toolchain), the test blesses
//!   the current digest: it writes the file (plus the full JSON trace next
//!   to it for diffing) and passes with a note. Commit the files to pin.
//!
//! ## Updating after an intentional behavior change
//!
//! Re-bless with `UPDATE_GOLDEN=1 cargo test --test golden_traces`, then
//! commit the updated `tests/golden/*` and describe the behavior change in
//! the PR. Digests are bit-stable per platform/toolchain (transcendentals
//! come from libm — see `experiments::scenarios::trace` for the full
//! determinism contract).

use std::path::PathBuf;

use daedalus::experiments::scenarios::{run_unit, ScenarioRegistry};

const GOLDEN_DURATION: u64 = 1_800;
const GOLDEN_SEED: u64 = 1;
const GOLDEN_STRIDE: u64 = 30;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Run the canonical unit for `approach` on `scenario` and check/bless its
/// digest under `tag`.
fn check_golden_on(scenario: &str, approach: &str, tag: &str) {
    let reg = ScenarioRegistry::builtin(GOLDEN_DURATION, &[GOLDEN_SEED]);
    let sc = reg.get(scenario).unwrap();
    let run = run_unit(sc, approach, GOLDEN_SEED, GOLDEN_STRIDE).unwrap();

    // In-process determinism: the same unit re-run must digest identically
    // even before any golden file exists.
    let rerun = run_unit(sc, approach, GOLDEN_SEED, GOLDEN_STRIDE).unwrap();
    assert_eq!(
        run.digest, rerun.digest,
        "{tag}: in-process rerun produced a different trace"
    );

    let dir = golden_dir();
    let digest_path = dir.join(format!("{tag}.digest"));
    let trace_path = dir.join(format!("{tag}.trace.json"));
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    match std::fs::read_to_string(&digest_path) {
        Ok(golden) if !update => {
            assert_eq!(
                golden.trim(),
                run.digest,
                "{tag}: trace digest drifted from {digest_path:?}; if the \
                 behavior change is intentional, re-bless with UPDATE_GOLDEN=1 \
                 and commit (full trace at {trace_path:?})"
            );
        }
        _ => {
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(&digest_path, format!("{}\n", run.digest)).unwrap();
            std::fs::write(&trace_path, run.trace.to_json()).unwrap();
            eprintln!(
                "blessed golden trace for {tag}: {} -> {digest_path:?}",
                run.digest
            );
        }
    }

    // Regardless of pinning, the canonical run must be structurally sane.
    assert_eq!(
        run.trace.points.len() as u64,
        GOLDEN_DURATION / GOLDEN_STRIDE
    );
    assert!(run.worker_seconds > 0.0);
}

/// Fused reference goldens (the paper's canonical cell).
fn check_golden(approach: &str) {
    check_golden_on("flink-wordcount-sine", approach, approach);
}

/// Staged-engine goldens on the canonical operator-elasticity cell.
fn check_staged_golden(approach: &str) {
    check_golden_on(
        "flink-wordcount-bottleneck-shift",
        approach,
        &format!("staged-{approach}"),
    );
}

#[test]
fn golden_trace_daedalus() {
    check_golden("daedalus");
}

#[test]
fn golden_trace_hpa() {
    check_golden("hpa-80");
}

#[test]
fn golden_trace_ds2() {
    check_golden("ds2");
}

#[test]
fn golden_trace_phoebe() {
    check_golden("phoebe");
}

#[test]
fn golden_trace_static() {
    check_golden("static-6");
}

#[test]
fn golden_trace_staged_daedalus() {
    check_staged_golden("daedalus");
}

#[test]
fn golden_trace_staged_hpa() {
    check_staged_golden("hpa-80");
}

#[test]
fn golden_trace_staged_ds2() {
    check_staged_golden("ds2");
}

#[test]
fn golden_trace_staged_ds2_job() {
    check_staged_golden("ds2-job");
}

#[test]
fn golden_trace_staged_phoebe() {
    check_staged_golden("phoebe");
}

#[test]
fn golden_trace_staged_static() {
    check_staged_golden("static-6");
}

// Demeter goldens on its two canonical multi-config cells: the digests
// pin the co-optimized runs — parallelism plans plus the `reconfigure`
// trace section (applied configs, consistent-cut timestamps).

#[test]
fn golden_trace_demeter_bottleneck_shift() {
    check_golden_on(
        "flink-wordcount-bottleneck-shift",
        "demeter",
        "demeter-bottleneck-shift",
    );
}

#[test]
fn golden_trace_demeter_diurnal_week() {
    check_golden_on(
        "flink-wordcount-diurnal-week",
        "demeter",
        "demeter-diurnal-week",
    );
}
