//! End-to-end perf smoke: the memory bounds of the tick-loop hot paths.
//!
//! Two unbounded-growth regressions are pinned here so they cannot
//! silently return:
//!
//! * **Partition queues** — same-timestamp chunk coalescing keeps every
//!   per-partition queue at one chunk per distinct arrival tick, so queue
//!   length is O(active backlog age), not O(run length × restarts).
//! * **ECDF storage** — the pooled latency distribution is a log-binned
//!   histogram with O(`Ecdf::MAX_BINS`) storage no matter how many fluid
//!   chunks a multi-hour run pushes (the old `Vec<(f64, f64)>` kept every
//!   sample).

use daedalus::dsp::{EngineProfile, SimConfig, Simulation};
use daedalus::jobs::JobProfile;
use daedalus::stats::Ecdf;
use daedalus::workload::ConstantWorkload;

#[test]
fn one_hour_sim_memory_stays_bounded() {
    // Adequately provisioned deployment (4 workers ≈ 22k cap, 12k load)
    // with two failure injections and a mid-run rescale: exercises replay
    // rewinds and catch-up backlogs, the paths that used to duplicate
    // same-timestamp chunks.
    let cfg = SimConfig {
        max_replicas: 18,
        seed: 17,
        rate_noise: 0.02,
        failures: vec![600, 1_800],
        ..SimConfig::base(
            EngineProfile::flink(),
            JobProfile::wordcount(),
            Box::new(ConstantWorkload {
                rate: 12_000.0,
                duration: 3_600,
            }),
        )
    };
    let mut sim = Simulation::new(cfg);
    let mut max_q = 0;
    for t in 0..3_600 {
        sim.step(t);
        if t == 2_400 {
            sim.request_rescale(8);
        }
        max_q = max_q.max(sim.max_queue_len());
    }
    sim.check_invariants();

    // Queue-length bound: downtime + catch-up spans a few hundred seconds
    // at most, and coalescing caps queues at one chunk per backlog tick.
    // Without coalescing, replay storms push this past the bound.
    assert!(max_q < 512, "per-partition queue grew to {max_q} chunks");
    // After catch-up the queues drain back to O(1).
    assert!(sim.max_queue_len() <= 8, "queues did not drain: {} left", sim.max_queue_len());

    // ECDF storage bound: hundreds of thousands of fluid-chunk samples
    // pooled into a fixed number of bins.
    let lat = sim.latencies();
    assert!(lat.len() > 100_000, "expected a multi-hour sample volume, got {}", lat.len());
    assert!(
        lat.bin_count() <= Ecdf::MAX_BINS,
        "ECDF storage exceeded the bin bound: {}",
        lat.bin_count()
    );
    assert!(lat.total_weight() > 0.0);
}
