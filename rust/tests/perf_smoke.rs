//! End-to-end perf smoke: the memory bounds of the tick-loop hot paths.
//!
//! Four unbounded-growth regressions are pinned here so they cannot
//! silently return:
//!
//! * **Partition queues** — same-timestamp chunk coalescing keeps every
//!   per-partition queue at one chunk per distinct arrival tick, so queue
//!   length is O(active backlog age), not O(run length × restarts).
//! * **ECDF storage** — the pooled latency distribution is a log-binned
//!   histogram with O(`Ecdf::MAX_BINS`) storage no matter how many fluid
//!   chunks a multi-hour run pushes (the old `Vec<(f64, f64)>` kept every
//!   sample).
//! * **Inter-stage bucket rings** — a stage queue spans one f64 bucket per
//!   backlogged arrival tick, so its occupancy is O(queued backlog age),
//!   bounded by the backpressure window plus restart gaps — not O(run
//!   length).
//! * **Columnar TSDB bytes** — a per-second series costs 8 bytes/sample
//!   plus a 16-byte run marker per serving gap, so a simulated hour stays
//!   near 8 bytes/tick/series (the retained pair layout costs a flat 16).
//!
//! Plus one unbounded-*work* regression: a noise-free month must be
//! committed by the tier-2 span integrator, keeping per-tick engine work
//! (slow core + tier-1 quiet ticks) at a fixed budget independent of the
//! horizon.

use daedalus::dsp::{EngineProfile, SimConfig, Simulation, StageModel};
use daedalus::jobs::JobProfile;
use daedalus::stats::Ecdf;
use daedalus::workload::ConstantWorkload;

#[test]
fn one_hour_sim_memory_stays_bounded() {
    // Adequately provisioned deployment (4 workers ≈ 22k cap, 12k load)
    // with two failure injections and a mid-run rescale: exercises replay
    // rewinds and catch-up backlogs, the paths that used to duplicate
    // same-timestamp chunks.
    let cfg = SimConfig {
        max_replicas: 18,
        seed: 17,
        rate_noise: 0.02,
        failures: vec![600, 1_800],
        ..SimConfig::base(
            EngineProfile::flink(),
            JobProfile::wordcount(),
            Box::new(ConstantWorkload {
                rate: 12_000.0,
                duration: 3_600,
            }),
        )
    };
    let mut sim = Simulation::new(cfg);
    let mut max_q = 0;
    for t in 0..3_600 {
        sim.step(t);
        if t == 2_400 {
            sim.request_rescale(8);
        }
        max_q = max_q.max(sim.max_queue_len());
    }
    sim.check_invariants();

    // Queue-length bound: downtime + catch-up spans a few hundred seconds
    // at most, and coalescing caps queues at one chunk per backlog tick.
    // Without coalescing, replay storms push this past the bound.
    assert!(max_q < 512, "per-partition queue grew to {max_q} chunks");
    // After catch-up the queues drain back to O(1).
    assert!(sim.max_queue_len() <= 8, "queues did not drain: {} left", sim.max_queue_len());

    // ECDF storage bound: hundreds of thousands of fluid-chunk samples
    // pooled into a fixed number of bins.
    let lat = sim.latencies();
    assert!(lat.len() > 100_000, "expected a multi-hour sample volume, got {}", lat.len());
    assert!(
        lat.bin_count() <= Ecdf::MAX_BINS,
        "ECDF storage exceeded the bin bound: {}",
        lat.bin_count()
    );
    assert!(lat.total_weight() > 0.0);

    // Columnar TSDB bound (fused): the hour's recordings stay near
    // 8 bytes/sample — run markers (one per serving gap per series) are
    // noise, not a second timestamp column.
    let db = sim.tsdb();
    let samples = db.samples_total();
    assert!(samples > 50_000, "expected an hour of metrics, got {samples}");
    assert!(
        db.sample_bytes() < samples * 9,
        "columnar TSDB spent {} bytes on {samples} samples (> 9 B/sample)",
        db.sample_bytes()
    );
}

#[test]
fn month_scale_quiet_run_is_span_integrated_with_fixed_tick_budget() {
    // Fully noise-free 30-day steady run: constant rate (`rate_noise == 0`
    // is the `SimConfig::base` default) and CPU noise zeroed, so
    // `noise_free_over` claims the whole horizon and `advance_quiet`
    // commits it through the tier-2 span closed form.
    const MONTH: u64 = 2_592_000;
    let mut profile = EngineProfile::flink();
    profile.cpu_noise = 0.0;
    let cfg = SimConfig {
        partitions: 12,
        initial_replicas: 4,
        seed: 9,
        ..SimConfig::base(
            profile,
            JobProfile::wordcount(),
            Box::new(ConstantWorkload {
                rate: 10_000.0,
                duration: MONTH,
            }),
        )
    };
    let mut sim = Simulation::new(cfg);
    sim.advance_quiet(0, MONTH);
    sim.check_invariants();

    // The O(1)-per-span pin: per-tick engine work is a fixed budget, not
    // O(horizon). On this run nothing interrupts the span, so the slow
    // core and the tier-1 per-tick closed form stay under a constant that
    // would be dwarfed instantly if the span path silently degraded.
    let per_tick = sim.ticks_slow_core() + sim.ticks_quiet_closed();
    assert!(per_tick <= 64, "per-tick engine work grew with the horizon: {per_tick} ticks");
    // Coverage identity: every tick lands in exactly one tier's counter,
    // and the span tiers carry essentially the entire month.
    assert_eq!(
        per_tick + sim.ticks_span_integrated() + sim.ticks_span_catchup(),
        MONTH,
        "tick coverage identity broken"
    );
    assert!(
        sim.ticks_span_integrated() >= MONTH - 64,
        "tier-2 spans covered only {} of {MONTH} ticks",
        sim.ticks_span_integrated()
    );

    // The month still produced a real run: conserved masses and a fully
    // populated latency distribution.
    assert!(sim.total_consumed() > 0.0);
    assert!(sim.latencies().total_weight() > 0.0);
}

#[test]
fn one_hour_staged_sim_ring_and_tsdb_stay_bounded() {
    // Staged deployment with a deliberately choked middle stage: the
    // inter-stage queues run at their backpressure bound the whole hour,
    // plus two failures and a mid-run per-stage rescale for replay storms.
    let cfg = SimConfig {
        partitions: 24,
        initial_replicas: 4,
        max_replicas: 12,
        seed: 23,
        rate_noise: 0.02,
        failures: vec![900, 2_200],
        stage_model: StageModel::Staged,
        ..SimConfig::base(
            EngineProfile::flink(),
            JobProfile::wordcount(),
            Box::new(ConstantWorkload {
                rate: 18_000.0,
                duration: 3_600,
            }),
        )
    };
    let mut sim = Simulation::new(cfg);
    sim.request_rescale_stages(&[4, 4, 1, 4]);
    let mut max_ring = 0;
    for t in 0..3_600 {
        sim.step(t);
        if t == 2_000 {
            sim.request_rescale_stages(&[4, 4, 2, 4]);
        }
        max_ring = max_ring.max(sim.max_stage_queue_len());
    }
    sim.check_invariants();

    // Ring-span bound: one bucket per backlogged tick — the backpressure
    // window (5 s of stage capacity) plus restart gaps is minutes of age,
    // not the hour of run time.
    assert!(max_ring < 512, "inter-stage ring grew to {max_ring} buckets");

    // Columnar TSDB bound: the staged engine records ~70 series every
    // serving tick for an hour; storage must stay near 8 bytes/sample
    // even with the restart-gap run splits.
    let db = sim.tsdb();
    let samples = db.samples_total();
    assert!(samples > 150_000, "expected an hour of staged metrics, got {samples}");
    assert!(
        db.sample_bytes() < samples * 9,
        "columnar TSDB spent {} bytes on {samples} samples (> 9 B/sample)",
        db.sample_bytes()
    );
}
