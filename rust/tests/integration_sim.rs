//! Integration tests over the whole Layer-3 stack: substrate + metric
//! store + autoscalers + harness, including failure injection.

use daedalus::autoscaler::{Autoscaler, Daedalus, DaedalusConfig, Hpa, HpaConfig, Static};
use daedalus::dsp::{EngineProfile, SimConfig, Simulation};
use daedalus::experiments::harness::{Approach, Experiment};
use daedalus::jobs::JobProfile;
use daedalus::metrics::SeriesId;
use daedalus::runtime::ComputeBackend;
use daedalus::workload::{ConstantWorkload, SineWorkload, StepWorkload};

fn drive(sim: &mut Simulation, scaler: &mut dyn Autoscaler, upto: u64) {
    for t in 0..upto {
        sim.step(t);
        if let Some(n) = scaler.decide(&sim.view()) {
            if scaler.wants_precheckpoint() {
                sim.checkpoint_now();
            }
            sim.request_rescale(n);
        }
        if t % 500 == 0 {
            sim.check_invariants();
        }
    }
}

#[test]
fn daedalus_tracks_sine_workload_end_to_end() {
    let job = JobProfile::wordcount();
    let peak = job.reference_peak;
    let mut sim = Simulation::new(SimConfig::paper(
        EngineProfile::flink(),
        job,
        Box::new(SineWorkload::paper_default(peak, 7_200)),
    ));
    let mut d = Daedalus::new(DaedalusConfig::default(), ComputeBackend::native());
    drive(&mut sim, &mut d, 7_200);

    // Economical: well under a static peak-sized deployment.
    assert!(sim.avg_workers() < 9.0, "avg {}", sim.avg_workers());
    // But functional: any remaining backlog is a few seconds of workload
    // at most (a rescale near the end may still be catching up).
    assert!(
        sim.total_backlog() < 10.0 * peak,
        "backlog {}",
        sim.total_backlog()
    );
    // It actually scaled both directions.
    let ups = sim.rescale_log.iter().filter(|e| e.to > e.from).count();
    let downs = sim.rescale_log.iter().filter(|e| e.to < e.from).count();
    assert!(ups >= 1 && downs >= 1, "ups {ups} downs {downs}");
}

#[test]
fn daedalus_survives_failure_injection() {
    let job = JobProfile::wordcount();
    let mut cfg = SimConfig::paper(
        EngineProfile::flink(),
        job,
        Box::new(ConstantWorkload {
            rate: 15_000.0,
            duration: 6_000,
        }),
    );
    cfg.failures = vec![1_000, 2_500];
    let mut sim = Simulation::new(cfg);
    let mut d = Daedalus::new(DaedalusConfig::default(), ComputeBackend::native());
    drive(&mut sim, &mut d, 6_000);

    let failures = sim.rescale_log.iter().filter(|e| e.failure).count();
    assert_eq!(failures, 2);
    // Recovered: backlog drained well before the end (recovery target is
    // 600 s; the last failure was 3 500 s before the end).
    assert!(
        sim.total_backlog() < 60_000.0,
        "backlog {}",
        sim.total_backlog()
    );
    // The anomaly-detection recovery monitor measured at least one
    // post-rescale recovery across the run.
    assert!(!d.knowledge().recoveries.is_empty() || d.knowledge().rescale_count == 0);
}

#[test]
fn static_deployment_never_rescales_after_setup() {
    let job = JobProfile::wordcount();
    let mut sim = Simulation::new(SimConfig::paper(
        EngineProfile::flink(),
        job,
        Box::new(SineWorkload::paper_default(20_000.0, 3_000)),
    ));
    let mut s = Static::new(12);
    drive(&mut sim, &mut s, 3_000);
    // One initial correction 4 → 12 at most.
    assert!(sim.rescale_log.len() <= 1);
    assert_eq!(sim.parallelism(), 12);
}

#[test]
fn hpa_follows_step_up() {
    let job = JobProfile::wordcount();
    let mut sim = Simulation::new(SimConfig::paper(
        EngineProfile::flink(),
        job,
        Box::new(StepWorkload {
            steps: vec![(0, 8_000.0), (1_000, 30_000.0)],
            duration: 4_000,
        }),
    ));
    let mut hpa = Hpa::new(HpaConfig::at_target(0.80, 18));
    drive(&mut sim, &mut hpa, 4_000);
    // 30k needs ≥ 6 nominal workers at 80 % target (6.8): HPA must have
    // scaled well beyond the initial 4.
    assert!(sim.parallelism() >= 6, "p {}", sim.parallelism());
    assert!(sim.total_backlog() < 100_000.0);
}

#[test]
fn experiment_harness_multi_seed_reproducible() {
    let job = JobProfile::wordcount();
    let backend = ComputeBackend::native();
    let make = |duration: u64| {
        Experiment::paper(
            "repro-check",
            EngineProfile::flink(),
            job.clone(),
            backend.clone(),
            duration,
        )
        .with_seeds(vec![7])
        .with_approaches(vec![Approach::Daedalus(DaedalusConfig::default())])
    };
    let peak = job.reference_peak;
    let r1 = make(2_400).run(&move |_| Box::new(SineWorkload::paper_default(peak, 2_400)));
    let r2 = make(2_400).run(&move |_| Box::new(SineWorkload::paper_default(peak, 2_400)));
    // Same seed ⇒ byte-identical trajectories.
    assert_eq!(
        r1.approaches[0].parallelism_series,
        r2.approaches[0].parallelism_series
    );
    assert_eq!(
        r1.approaches[0].worker_seconds,
        r2.approaches[0].worker_seconds
    );
    assert_eq!(
        r1.approaches[0].avg_latency_ms(),
        r2.approaches[0].avg_latency_ms()
    );
}

#[test]
fn kstreams_hpa80_underprovisions_but_hpa60_keeps_up() {
    // The Fig-10 mechanism as an integration test.
    let job = JobProfile::wordcount();
    let peak = job.reference_peak;
    let run = |target: f64| {
        let mut sim = Simulation::new(SimConfig::paper(
            EngineProfile::kstreams(),
            job.clone(),
            Box::new(SineWorkload::paper_default(peak, 5_400)),
        ));
        let mut hpa = Hpa::new(HpaConfig::at_target(target, 12));
        drive(&mut sim, &mut hpa, 5_400);
        (sim.avg_workers(), sim.latencies().clone().mean())
    };
    let (w80, lat80) = run(0.80);
    let (w60, lat60) = run(0.60);
    assert!(w80 < w60, "hpa-80 {w80} should allocate less than hpa-60 {w60}");
    assert!(
        lat80 > 5.0 * lat60,
        "hpa-80 latency {lat80} should collapse vs hpa-60 {lat60}"
    );
}

#[test]
fn tsdb_series_are_consistent_during_run() {
    let job = JobProfile::ysb();
    let mut sim = Simulation::new(SimConfig::paper(
        EngineProfile::flink(),
        job,
        Box::new(ConstantWorkload {
            rate: 20_000.0,
            duration: 1_200,
        }),
    ));
    let mut d = Daedalus::new(DaedalusConfig::default(), ComputeBackend::native());
    drive(&mut sim, &mut d, 1_200);
    let db = sim.tsdb();
    // Workload recorded every tick.
    assert_eq!(db.len(&SeriesId::global("workload_rate")), 1_200);
    assert_eq!(db.len(&SeriesId::global("consumer_lag")), 1_200);
    assert_eq!(db.len(&SeriesId::global("parallelism")), 1_200);
    // Throughput only while serving — rescales cause gaps.
    let tput = db.len(&SeriesId::global("throughput"));
    assert!(tput <= 1_200);
    let down: u64 = sim
        .rescale_log
        .iter()
        .map(|e| e.downtime_secs.ceil() as u64)
        .sum();
    assert!(tput as u64 >= 1_200 - down - 60, "tput {tput}, down {down}");
}
