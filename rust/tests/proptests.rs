//! Property-based tests on coordinator invariants.
//!
//! The proptest crate is unavailable in this offline environment, so the
//! generators are built on the crate's own deterministic PRNG: each
//! property runs against a few hundred random cases with a fixed seed
//! sweep — failures print the offending case parameters.

use daedalus::autoscaler::daedalus::analyze::CapacityEstimates;
use daedalus::autoscaler::daedalus::forecasting::ForecastResult;
use daedalus::autoscaler::daedalus::knowledge::Knowledge;
use daedalus::autoscaler::daedalus::monitor::MonitorData;
use daedalus::autoscaler::daedalus::plan::plan_scale_out;
use daedalus::autoscaler::DaedalusConfig;
use daedalus::dsp::Partition;
use daedalus::runtime::{native, ArtifactMeta, CapacityState};
use daedalus::stats::{wape, Ecdf, Rng, Welford};

fn caps(per_worker: f64, parallelism: usize) -> CapacityEstimates {
    CapacityEstimates {
        per_worker: vec![per_worker; parallelism],
        current: per_worker * parallelism as f64,
        parallelism,
        avg_per_worker: per_worker,
        seen: Default::default(),
    }
}

fn monitor(avg: f64, lag: f64, parallelism: usize) -> MonitorData {
    MonitorData {
        now: 5_000,
        history: vec![avg; 1800],
        workload_avg: avg,
        workload_max: avg,
        consumer_lag: lag,
        parallelism,
        ..MonitorData::empty()
    }
}

/// Property: Algorithm 1 always returns a scale-out in [1, max]; and when
/// *some* scale-out both covers the workload and recovers in time, the
/// chosen one covers the observed average workload.
#[test]
fn prop_plan_output_in_bounds_and_sufficient() {
    let cfg = DaedalusConfig::default();
    let k = Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0);
    let mut rng = Rng::new(0xA11CE);
    for case in 0..500 {
        let per_worker = rng.range(500.0, 10_000.0);
        let max = 1 + rng.below(31) as usize;
        let current = 1 + rng.below(max as u64) as usize;
        let avg = rng.range(100.0, per_worker * max as f64 * 1.5);
        let lag = if rng.f64() < 0.3 {
            rng.range(0.0, 1e7)
        } else {
            0.0
        };
        let forecast = ForecastResult {
            values: vec![avg; 900],
            from_model: true,
            prev_wape: None,
        };
        let d = monitor(avg, lag, current);
        let decision = plan_scale_out(5_000, &caps(per_worker, current), &d, &forecast, &k, &cfg, max);
        assert!(
            decision.target >= 1 && decision.target <= max,
            "case {case}: out of bounds {decision:?} (max {max})"
        );
        // If even max cannot cover the workload, the algorithm must return
        // max (the fallback line of Algorithm 1).
        if per_worker * max as f64 <= avg {
            assert_eq!(decision.target, max, "case {case}");
        }
    }
}

/// Property: the plan is monotone in workload — more load never yields a
/// smaller scale-out (all else equal, no lag, fresh knowledge).
#[test]
fn prop_plan_monotone_in_workload() {
    let cfg = DaedalusConfig::default();
    let k = Knowledge::new(&ArtifactMeta::default(), 30.0, 15.0);
    let mut rng = Rng::new(0xB0B);
    for case in 0..200 {
        let per_worker = rng.range(1_000.0, 8_000.0);
        let max = 12 + rng.below(7) as usize;
        let current = 1 + rng.below(max as u64) as usize;
        let lo = rng.range(500.0, per_worker * 6.0);
        let hi = lo * rng.range(1.1, 2.0);
        let plan_for = |w: f64| {
            let forecast = ForecastResult {
                values: vec![w; 900],
                from_model: true,
                prev_wape: None,
            };
            plan_scale_out(
                5_000,
                &caps(per_worker, current),
                &monitor(w, 0.0, current),
                &forecast,
                &k,
                &cfg,
                max,
            )
            .target
        };
        let a = plan_for(lo);
        let b = plan_for(hi);
        assert!(
            b >= a,
            "case {case}: workload {lo}→{hi} but plan {a}→{b} (per_worker {per_worker}, current {current}, max {max})"
        );
    }
}

/// Property: partition offsets are conserved through arbitrary sequences
/// of produce/consume/checkpoint/rewind.
#[test]
fn prop_partition_conservation() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let mut p = Partition::new();
        for t in 0..400 {
            match rng.below(10) {
                0..=4 => p.produce(t as f64, rng.range(0.0, 5_000.0)),
                5..=7 => {
                    p.consume(rng.range(0.0, 6_000.0));
                }
                8 => p.checkpoint(),
                _ => p.rewind(),
            }
            p.check_invariants();
            assert!(p.committed <= p.consumed + 1e-6);
            assert!(p.consumed <= p.produced + 1e-6);
            assert!(p.lag() >= -1e-6);
            assert!(p.backlog() >= -1e-6);
        }
    }
}

/// Property: FIFO — chunks come out of a partition in non-decreasing
/// arrival-time order between rewinds.
#[test]
fn prop_partition_fifo_order() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0xF1F0);
        let mut p = Partition::new();
        let mut last_t = f64::MIN;
        for t in 0..300 {
            p.produce(t as f64, rng.range(1.0, 100.0));
            for c in p.consume(rng.range(0.0, 120.0)) {
                assert!(
                    c.t >= last_t - 1e-9,
                    "seed {seed}: out of order {} after {}",
                    c.t,
                    last_t
                );
                last_t = c.t;
            }
        }
    }
}

/// Property: native capacity model — capacity prediction scales linearly
/// with throughput scale and is invariant to observation order.
#[test]
fn prop_capacity_scale_invariance() {
    let meta = ArtifactMeta::default();
    let mut rng = Rng::new(42);
    for case in 0..100 {
        let b = meta.obs_block;
        let mw = meta.max_workers;
        let mut xs = vec![0.0f32; mw * b];
        let mut ys = vec![0.0f32; mw * b];
        let mask = vec![1.0f32; mw * b];
        let slope = rng.range(1_000.0, 50_000.0);
        for i in 0..mw * b {
            let x = rng.range(0.1, 0.95);
            xs[i] = x as f32;
            ys[i] = (slope * x) as f32;
        }
        let tgt = vec![1.0f32; mw];
        let state = CapacityState::zeros(mw);
        let out1 = native::capacity_update(&meta, &state, &xs, &ys, &mask, &tgt).unwrap();
        // Double the throughputs → double the capacity.
        let ys2: Vec<f32> = ys.iter().map(|y| y * 2.0).collect();
        let out2 = native::capacity_update(&meta, &state, &xs, &ys2, &mask, &tgt).unwrap();
        for w in 0..mw {
            let (a, b2) = (out1.capacities[w], out2.capacities[w]);
            assert!(
                (b2 - 2.0 * a).abs() <= 0.02 * (a.abs() * 2.0) + 1.0,
                "case {case} worker {w}: {a} vs {b2}"
            );
        }
    }
}

/// Property: the forecast of any bounded non-negative series stays inside
/// the physical envelope [0, 8 × max(history)] and is always finite.
#[test]
fn prop_forecast_bounded_envelope() {
    let meta = ArtifactMeta::default();
    let mut rng = Rng::new(7);
    for case in 0..60 {
        let level = rng.range(10.0, 1e5);
        let hist: Vec<f32> = (0..meta.window)
            .map(|t| {
                let base = level * (1.0 + 0.5 * (t as f64 / rng.range(50.0, 2_000.0)).sin());
                (base + rng.normal() * level * 0.1).max(0.0) as f32
            })
            .collect();
        let out = native::forecast(&meta, &hist).unwrap();
        let hi = 8.0 * hist.iter().copied().fold(0.0f32, f32::max) as f64 + 1.0;
        for (i, v) in out.forecast.iter().enumerate() {
            assert!(v.is_finite(), "case {case} step {i}: not finite");
            assert!(
                (*v as f64) >= 0.0 && (*v as f64) <= hi,
                "case {case} step {i}: {v} outside [0, {hi}]"
            );
        }
    }
}

/// Property: engine-level conservation under random rescale/failure storms.
/// At every checkpoint: produced = consumed + backlog (per partition, so in
/// total), worker-seconds equals the integral of allocated workers, and all
/// latency samples are non-negative and finite.
#[test]
fn prop_engine_conservation_under_random_rescales() {
    use daedalus::dsp::{EngineProfile, SimConfig, Simulation};
    use daedalus::jobs::JobProfile;
    use daedalus::workload::SineWorkload;

    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0xE46);
        let failures = if seed % 2 == 0 { vec![700, 1_500] } else { vec![] };
        let cfg = SimConfig {
            partitions: 36,
            initial_replicas: 1 + rng.below(12) as usize,
            seed,
            rate_noise: 0.02,
            failures,
            ..SimConfig::base(
                if seed % 3 == 0 {
                    EngineProfile::kstreams()
                } else {
                    EngineProfile::flink()
                },
                JobProfile::wordcount(),
                Box::new(SineWorkload::paper_default(20_000.0, 2_400)),
            )
        };
        let mut sim = Simulation::new(cfg);
        let mut alloc_integral = 0.0;
        for t in 0..2_400 {
            sim.step(t);
            alloc_integral += sim
                .tsdb()
                .last_at(&daedalus::metrics::SeriesId::global("allocated_workers"), t)
                .unwrap()
                .1;
            // Random rescale storm: ~1 request / 100 s (most are ignored
            // mid-restart — also exercised).
            if rng.below(100) == 0 {
                sim.request_rescale(1 + rng.below(12) as usize);
            }
            if t % 240 == 0 {
                sim.check_invariants();
            }
        }
        sim.check_invariants();
        assert!(
            (sim.worker_seconds() - alloc_integral).abs() < 1e-6,
            "seed {seed}: worker-seconds {} vs integral {alloc_integral}",
            sim.worker_seconds()
        );
        assert!(sim.latencies().total_weight() > 0.0);
    }
}

/// Property: any valid sequence of runtime-config actions applied
/// mid-run — random checkpoint intervals, backpressure bounds, and
/// per-stage queue-bound overrides (grows and shrinks alike, so bounds
/// tighten onto live queue mass) — preserves flow conservation on the
/// fused and the staged engine, with and without a typed fault storm
/// riding along. `Simulation::check_invariants` pins `upstream emitted
/// == consumed + queued` for every inter-stage queue, so a reconfigure
/// that dropped in-flight records would trip it; the reconfigure log is
/// additionally checked for consistent-cut semantics (each applied
/// config landed at or after its request, never more applications than
/// accepted requests).
#[test]
fn prop_random_config_sequences_preserve_flow_conservation() {
    use daedalus::dsp::{
        EngineProfile, FaultEvent, FaultTimeline, RuntimeConfig, SimConfig, Simulation, StageModel,
    };
    use daedalus::jobs::JobProfile;
    use daedalus::workload::ShapeKind;

    let duration = 1_200u64;
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0xC0F6);
        // Even seeds run under a typed fault storm: a partial crash, a
        // gray straggler spanning several cuts, and a checkpoint loss
        // (the replay path) all overlap the random config actions.
        let faults = if seed % 2 == 0 {
            FaultTimeline::new(vec![
                FaultEvent::WorkerCrash { t: 300, k: 2 },
                FaultEvent::GrayFailure {
                    from: 500,
                    to: 800,
                    worker: 1,
                    severity: 0.5,
                },
                FaultEvent::CheckpointLoss { t: 900 },
            ])
        } else {
            FaultTimeline::default()
        };
        for staged in [false, true] {
            let cfg = SimConfig {
                partitions: 24,
                initial_replicas: if staged { 2 } else { 4 },
                seed,
                rate_noise: 0.02,
                faults: faults.clone(),
                stage_model: if staged {
                    StageModel::Staged
                } else {
                    StageModel::Fused
                },
                ..SimConfig::base(
                    EngineProfile::flink(),
                    JobProfile::wordcount(),
                    ShapeKind::Sine.build(14_000.0, duration, seed),
                )
            };
            let mut sim = Simulation::new(cfg);
            let mut accepted = 0usize;
            for t in 0..duration {
                sim.step(t);
                // ~1 config action / 50 s, always inside the valid
                // domain; a zero per-stage entry falls back to the
                // default bound, small entries force mid-backlog shrinks.
                if rng.below(50) == 0 {
                    let n_bounds = rng.below(4) as usize;
                    let config = RuntimeConfig {
                        checkpoint_interval: 1 + rng.below(30),
                        backpressure_secs: rng.range(0.5, 12.0),
                        queue_bound_secs: (0..n_bounds).map(|_| rng.range(0.0, 8.0)).collect(),
                    };
                    assert!(config.is_valid(), "generator left the valid domain");
                    if sim.request_reconfigure(config) {
                        accepted += 1;
                    }
                }
                if t % 200 == 0 {
                    sim.check_invariants();
                }
            }
            sim.check_invariants();
            let what = format!("seed {seed} staged={staged}");
            // Consistent-cut bookkeeping: a request may be superseded
            // while pending, but never applied twice or retroactively.
            let pending = usize::from(sim.pending_reconfigure().is_some());
            assert!(
                sim.reconfigure_log.len() + pending <= accepted,
                "{what}: {} applications + {pending} pending from {accepted} accepted",
                sim.reconfigure_log.len()
            );
            for ev in &sim.reconfigure_log {
                assert!(ev.t >= ev.requested_at, "{what}: applied before request");
                assert!(ev.config.is_valid(), "{what}: invalid config applied");
            }
            if !staged {
                let produced = sim.total_produced();
                let consumed = sim.total_consumed();
                let backlog = sim.total_backlog();
                assert!(
                    (produced - consumed - backlog).abs() < 1e-6 * produced.max(1.0),
                    "{what}: produced {produced} != consumed {consumed} + backlog {backlog}"
                );
            }
            assert!(
                sim.latencies().total_weight() > 0.0,
                "{what}: no tuples processed"
            );
        }
    }
}

/// Property: every autoscaler fed an empty or all-None metric window — a
/// fresh store with no samples, or a populated store hidden behind a
/// whole-horizon dropout lens — holds (returns no plan) at every tick of
/// a warm-up-clearing sweep, on the fused and the staged view, without
/// panicking. This is the shared [`daedalus::autoscaler::guard`]
/// contract: missing inputs degrade to "do nothing", never to a garbage
/// plan or a crash. The unguarded Daedalus ablation is included: even
/// without the degraded-telemetry hold, an all-None window must read as
/// "no workers observed", not as zeros to plan on.
#[test]
fn prop_every_autoscaler_holds_on_empty_or_all_none_window() {
    use daedalus::autoscaler::phoebe::profile_job;
    use daedalus::autoscaler::{
        Autoscaler, Daedalus, Demeter, DemeterConfig, Ds2, Ds2Config, Hpa, HpaConfig, Phoebe,
        PhoebeConfig, Static,
    };
    use daedalus::dsp::engine::SimView;
    use daedalus::dsp::{EngineProfile, TelemetryFaultEvent, TelemetryFaultTimeline, TelemetryLens};
    use daedalus::jobs::JobProfile;
    use daedalus::metrics::Tsdb;
    use daedalus::runtime::ComputeBackend;

    let parallelism = 4usize;
    let max_replicas = 12usize;
    let stages = [parallelism; 3];

    // A populated store whose every sample sits inside a whole-horizon
    // dropout window: reads resolve None exactly like the fresh store's.
    let mut populated = Tsdb::new();
    for t in 0..600u64 {
        populated.record_global("workload_rate", t, 15_000.0);
        populated.record_global("consumer_lag", t, 0.0);
        for w in 0..parallelism {
            populated.record_worker("worker_cpu", w, t, 0.7);
            populated.record_worker("worker_throughput", w, t, 4_000.0);
        }
    }
    let blackout = TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricDropout {
        from: 0,
        to: u64::MAX,
    }]);
    let clean = TelemetryFaultTimeline::default();
    let empty = Tsdb::new();

    let build_scalers = || -> Vec<Box<dyn Autoscaler>> {
        let profiled = profile_job(
            &EngineProfile::flink(),
            &JobProfile::wordcount(),
            &[2, 4, 8],
            max_replicas,
            0x9F0E,
        );
        vec![
            Box::new(Daedalus::new(
                daedalus::autoscaler::DaedalusConfig::default(),
                ComputeBackend::native(),
            )),
            Box::new(Daedalus::new(
                daedalus::autoscaler::DaedalusConfig {
                    hardened: false,
                    ..daedalus::autoscaler::DaedalusConfig::default()
                },
                ComputeBackend::native(),
            )),
            Box::new(Demeter::new(
                daedalus::autoscaler::DaedalusConfig::default(),
                DemeterConfig::default(),
                ComputeBackend::native(),
            )),
            Box::new(Hpa::new(HpaConfig::at_target(0.8, max_replicas))),
            Box::new(Ds2::new(Ds2Config::defaults(max_replicas))),
            Box::new(Ds2::job_level(Ds2Config::defaults(max_replicas))),
            Box::new(Phoebe::new(
                PhoebeConfig::default(),
                profiled.models,
                ComputeBackend::native(),
            )),
            Box::new(Static::new(parallelism)),
        ]
    };

    for (label, db, tl) in [
        ("fresh-store", &empty, &clean),
        ("dropout-blackout", &populated, &blackout),
    ] {
        for staged in [false, true] {
            for mut scaler in build_scalers() {
                for now in 0..600u64 {
                    let view = SimView {
                        now,
                        tsdb: TelemetryLens::new(db, tl, now),
                        parallelism,
                        ready: true,
                        max_replicas,
                        stage_parallelism: if staged { &stages } else { &[] },
                        dropped_rescales: 0,
                    };
                    let plan = scaler.decide_plan(&view);
                    assert!(
                        plan.is_none(),
                        "{label}/staged={staged}/{}/t={now}: planned {plan:?}",
                        scaler.name()
                    );
                }
            }
        }
    }
}

/// Property: Welford fold order-independence (statistics are permutation
/// invariant up to floating-point tolerance).
#[test]
fn prop_welford_permutation_invariant() {
    let mut rng = Rng::new(99);
    for _ in 0..50 {
        let n = 50 + rng.below(200) as usize;
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.range(0.0, 1.0), rng.range(0.0, 1e5)))
            .collect();
        let mut fwd = Welford::new();
        for (x, y) in &pts {
            fwd.push(*x, *y);
        }
        let mut rev = Welford::new();
        for (x, y) in pts.iter().rev() {
            rev.push(*x, *y);
        }
        assert!((fwd.mean_x - rev.mean_x).abs() < 1e-9);
        assert!((fwd.cov() - rev.cov()).abs() < 1e-6 * fwd.cov().abs().max(1.0));
        assert!((fwd.var_x() - rev.var_x()).abs() < 1e-9);
    }
}

/// Property: ECDF quantiles are monotone in q and bounded by min/max.
#[test]
fn prop_ecdf_quantile_monotone() {
    let mut rng = Rng::new(1234);
    for _ in 0..50 {
        let mut e = Ecdf::new();
        let n = 1 + rng.below(500);
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for _ in 0..n {
            let v = rng.range(0.0, 1e6);
            let w = rng.range(0.01, 10.0);
            lo = lo.min(v);
            hi = hi.max(v);
            e.push(v, w);
        }
        let mut prev = f64::MIN;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = e.quantile(q);
            assert!(v >= prev - 1e-12, "quantile not monotone at q={q}");
            assert!(v >= lo && v <= hi);
            prev = v;
        }
    }
}

/// Property: WAPE is shift-sensitive but scale-invariant:
/// wape(k·a, k·f) == wape(a, f) for k > 0.
#[test]
fn prop_wape_scale_invariant() {
    let mut rng = Rng::new(555);
    for _ in 0..100 {
        let n = 1 + rng.below(100) as usize;
        let a: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1e5)).collect();
        let f: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1e5)).collect();
        let k = rng.range(0.1, 100.0);
        let ka: Vec<f64> = a.iter().map(|v| v * k).collect();
        let kf: Vec<f64> = f.iter().map(|v| v * k).collect();
        let w1 = wape(&a, &f).unwrap();
        let w2 = wape(&ka, &kf).unwrap();
        assert!((w1 - w2).abs() < 1e-9, "{w1} vs {w2}");
    }
}
