//! Report determinism: `daedalus report`'s output must be a pure function
//! of `(sections, duration, seeds)` — byte-identical across in-process
//! reruns and across thread counts — and is digest-pinned alongside the
//! golden traces.
//!
//! Pinning mirrors `tests/golden_traces.rs`: the markdown's FNV-1a digest
//! is compared against `tests/golden/report.digest`; a fresh checkout
//! self-blesses (writes the digest plus the full `REPORT.md` next to it
//! for diffing), and intentional protocol/rendering changes re-bless with
//! `UPDATE_GOLDEN=1` plus a rationale in the PR. Digests are per-platform
//! stable (transcendentals come from libm); the in-process double-run
//! byte-equality holds everywhere.

use std::path::PathBuf;

use daedalus::experiments::evaluate::{self, EvalOptions, SectionSpec};
use daedalus::util::fnv1a_hex;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// The truncated selection: one fused paper cell and one staged
/// operator-elasticity cell, trimmed approach lists, short horizon.
fn truncated() -> (Vec<SectionSpec>, EvalOptions) {
    let mut sections = evaluate::sections_by_ids(&["fused-flink", "staged"]).unwrap();
    sections[0].scenarios.retain(|s| s == "flink-wordcount-sine");
    sections[0].approaches = vec!["daedalus".into(), "static-12".into()];
    sections[1].scenarios.retain(|s| s == "flink-wordcount-bottleneck-shift");
    sections[1].approaches = vec!["ds2".into(), "ds2-job".into()];
    let opts = EvalOptions {
        duration: 900,
        seeds: vec![1, 2],
        threads: 0,
    };
    (sections, opts)
}

#[test]
fn report_is_byte_identical_across_runs_and_thread_counts_and_digest_pinned() {
    let (sections, opts) = truncated();
    let a = evaluate::run(&sections, &opts).unwrap();
    // Rerun with default threading, then serially: bytes cannot differ.
    let b = evaluate::run(&sections, &opts).unwrap();
    let serial_opts = EvalOptions {
        threads: 1,
        ..opts.clone()
    };
    let serial = evaluate::run(&sections, &serial_opts).unwrap();
    let md = a.markdown();
    assert_eq!(md, b.markdown(), "in-process rerun changed REPORT.md bytes");
    assert_eq!(md, serial.markdown(), "thread count changed REPORT.md bytes");
    assert_eq!(a.csv(), b.csv());
    assert_eq!(a.json(), serial.json());

    // Structure: both engines' sections rendered, the reduction column and
    // headline present, machine-readable rows parse.
    assert!(md.contains("flink-wordcount-sine"));
    assert!(md.contains("flink-wordcount-bottleneck-shift"));
    assert!(md.contains("vs static-12") && md.contains("vs ds2-job"));
    assert!(a.csv().contains("reduction_vs_baseline_pct"));
    let json = daedalus::util::json::Json::parse(&a.json()).unwrap();
    assert_eq!(
        json.get("schema").unwrap().as_str().unwrap(),
        "daedalus-report/v1"
    );
    // The staged granularity dividend shows up in the report itself:
    // per-operator DS2 undercuts job-level DS2 on bottleneck-shift.
    let staged = &a.sections[1];
    let red = staged.reduction_vs("ds2-job", false).unwrap();
    assert!(red > 0.0, "per-operator DS2 should save resources: {red}%");

    // Digest-pin the markdown next to the golden traces (self-blessing,
    // UPDATE_GOLDEN=1 to re-bless after an intentional change).
    let digest = fnv1a_hex(md.as_bytes());
    let dir = golden_dir();
    let digest_path = dir.join("report.digest");
    let report_path = dir.join("report.REPORT.md");
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    match std::fs::read_to_string(&digest_path) {
        Ok(golden) if !update => {
            assert_eq!(
                golden.trim(),
                digest,
                "REPORT.md bytes drifted from {digest_path:?}; if the \
                 protocol/rendering change is intentional, re-bless with \
                 UPDATE_GOLDEN=1 and commit (full report at {report_path:?})"
            );
        }
        _ => {
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(&digest_path, format!("{digest}\n")).unwrap();
            std::fs::write(&report_path, &md).unwrap();
            eprintln!("blessed report digest: {digest} -> {digest_path:?}");
        }
    }
}

#[test]
fn report_write_emits_all_artifacts() {
    let (mut sections, mut opts) = truncated();
    // Smallest possible write check: one section, one seed.
    sections.truncate(1);
    opts.seeds = vec![1];
    let eval = evaluate::run(&sections, &opts).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "daedalus-report-write-test-{}",
        std::process::id()
    ));
    let out = eval.write(dir.to_str().unwrap()).unwrap();
    let report = std::fs::read_to_string(out.join("REPORT.md")).unwrap();
    assert_eq!(report, eval.markdown(), "written file differs from render");
    let csv = std::fs::read_to_string(out.join("report.csv")).unwrap();
    // Header + one row per (scenario × approach).
    assert_eq!(csv.trim().lines().count(), 1 + eval.sections[0].rows.len());
    assert!(out.join("report.json").exists());
    assert!(out.join("ecdf_flink-wordcount-sine.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}
