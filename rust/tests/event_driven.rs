//! Event-driven engine-core agreement tests: the quiet-span fast path
//! ([`EngineMode::EventDriven`], the default) must reproduce the retained
//! per-tick reference ([`EngineMode::PerTick`]) bit for bit — same trace
//! digest, same pooled latencies, same worker-seconds — on **every**
//! registry cell, and it must carry a truncated week/month-scale run
//! without violating the conservation invariants CI depends on.

use daedalus::dsp::EngineMode;
use daedalus::experiments::scenarios::ScenarioRegistry;

/// Run one (scenario, approach, seed) unit under `mode` and return the
/// full `(RunResult, RunTrace)` pair.
fn run_unit(
    scenario: &daedalus::experiments::Scenario,
    approach: &daedalus::experiments::Approach,
    seed: u64,
    mode: EngineMode,
    stride: u64,
) -> (
    daedalus::experiments::harness::RunResult,
    daedalus::experiments::scenarios::RunTrace,
) {
    let mut exp = scenario.to_experiment().unwrap();
    exp.engine_mode = mode;
    exp.run_single_traced(approach, seed, scenario.workload(seed), stride)
}

/// Assert that one unit's event-driven run equals its per-tick run in
/// every observable: quantized trace digest, and exact (bitwise) resource
/// and latency accounting.
fn assert_modes_agree(
    scenario: &daedalus::experiments::Scenario,
    approach: &daedalus::experiments::Approach,
    seed: u64,
    stride: u64,
) {
    let (ra, ta) = run_unit(scenario, approach, seed, EngineMode::PerTick, stride);
    let (rb, tb) = run_unit(scenario, approach, seed, EngineMode::EventDriven, stride);
    let unit = format!("{}/{}/seed-{seed}", scenario.name, approach.label());
    assert_eq!(ta.digest(), tb.digest(), "trace digest drift for {unit}");
    assert_eq!(ta.points, tb.points, "trace points drift for {unit}");
    assert_eq!(ta.events, tb.events, "trace events drift for {unit}");
    assert_eq!(
        ra.worker_seconds.to_bits(),
        rb.worker_seconds.to_bits(),
        "worker-seconds drift for {unit}"
    );
    assert_eq!(
        ra.final_backlog.to_bits(),
        rb.final_backlog.to_bits(),
        "final-backlog drift for {unit}"
    );
    assert_eq!(
        ra.lag_max.to_bits(),
        rb.lag_max.to_bits(),
        "lag-max drift for {unit}"
    );
    assert_eq!(ra.latencies, rb.latencies, "latency ECDF drift for {unit}");
    assert_eq!(
        ra.parallelism_series, rb.parallelism_series,
        "parallelism-series drift for {unit}"
    );
    assert_eq!(ra.rescales, rb.rescales, "rescale-count drift for {unit}");
    assert_eq!(
        ra.dropped_rescales, rb.dropped_rescales,
        "dropped-rescale drift for {unit}"
    );
    assert_eq!(
        ra.restart_retries, rb.restart_retries,
        "restart-retry drift for {unit}"
    );
}

/// Every built-in registry cell, every approach it carries: the two engine
/// modes must agree exactly. This is the PR's flagship pin — it covers the
/// fused and staged serve paths, all five autoscalers' `next_decision`
/// bounds, failure injection, and the deferred-TSDB bulk fills, all at a
/// CI-sized duration.
#[test]
fn event_driven_matches_per_tick_on_every_registry_cell() {
    let reg = ScenarioRegistry::builtin(900, &[3]);
    for scenario in reg.scenarios() {
        let exp = scenario.to_experiment().unwrap();
        for approach in &exp.approaches {
            assert_modes_agree(scenario, approach, 3, 60);
        }
    }
}

/// The typed-fault chaos cells must stay in the registry: the bitwise pin
/// above iterates `ScenarioRegistry::builtin`, so its coverage of the
/// fault taxonomy (mixed chaos, crash-loop storm, gray-failure week) is
/// only as good as these cells' continued presence.
#[test]
fn chaos_cells_stay_in_the_registry_wide_bitwise_pin() {
    let reg = ScenarioRegistry::builtin(900, &[3]);
    for name in [
        "flink-wordcount-sine-chaos",
        "flink-wordcount-bottleneck-shift-chaos",
        "flink-wordcount-sine-crashloop3",
        "flink-wordcount-diurnal-week-grayweek",
    ] {
        let scenario = reg
            .get(name)
            .unwrap_or_else(|| panic!("{name} missing: the registry-wide pin lost its fault coverage"));
        let exp = scenario.to_experiment().unwrap();
        assert!(
            !exp.faults.events().is_empty(),
            "{name}: chaos cell carries no typed faults"
        );
    }
}

/// The telemetry chaos cells must stay in the registry for the same
/// reason: the registry-wide pin's coverage of the degraded-telemetry
/// taxonomy (flash-crowd blackout, 5-minute staleness, spike-storm
/// corruption + actuator denial) — and of the hardened-vs-unguarded
/// Daedalus ablation those cells carry — rests on their presence.
#[test]
fn telemetry_cells_stay_in_the_registry_wide_bitwise_pin() {
    let reg = ScenarioRegistry::builtin(900, &[3]);
    for name in [
        "flink-wordcount-flash-crowd-blackout",
        "flink-wordcount-diurnal-week-stale5m",
        "flink-wordcount-sine-spikestorm",
    ] {
        let scenario = reg.get(name).unwrap_or_else(|| {
            panic!("{name} missing: the registry-wide pin lost its telemetry-fault coverage")
        });
        let exp = scenario.to_experiment().unwrap();
        assert!(
            !exp.telemetry.is_empty(),
            "{name}: telemetry chaos cell carries no telemetry faults"
        );
        assert!(
            exp.approaches.iter().any(|a| a.label() == "daedalus-unguarded"),
            "{name}: telemetry chaos cell lost its unguarded ablation arm"
        );
    }
}

/// The demeter multi-config cells must stay in the registry for the same
/// reason: the registry-wide pin's coverage of the reconfiguration path —
/// runtime-config proposals issued on the planning cadence, staged
/// through `request_reconfigure`, and applied at consistent cuts under
/// both engine drivers — rests on these cells carrying the `demeter`
/// arm.
#[test]
fn demeter_cells_stay_in_the_registry_wide_bitwise_pin() {
    let reg = ScenarioRegistry::builtin(900, &[3]);
    for name in [
        "flink-wordcount-bottleneck-shift",
        "flink-wordcount-diurnal-week",
    ] {
        let scenario = reg.get(name).unwrap_or_else(|| {
            panic!("{name} missing: the registry-wide pin lost its reconfiguration coverage")
        });
        let exp = scenario.to_experiment().unwrap();
        assert!(
            exp.approaches.iter().any(|a| a.label() == "demeter"),
            "{name}: cell lost its multi-config arm"
        );
    }
}

/// Every telemetry fault class, with the hardened Daedalus *and* its
/// unguarded ablation in the loop, on a fused and a staged cell: the
/// harness folds telemetry boundaries into the quiet-span horizon as
/// advisory bounds and steps densely while a read fault is active, and
/// the default `decide_is_noop_over` refuses spans over degraded ranges —
/// so EventDriven must equal PerTick bitwise even while guards engage,
/// hold plans, and cool down mid-run.
#[test]
fn event_driven_matches_per_tick_under_every_telemetry_fault_class() {
    use daedalus::autoscaler::DaedalusConfig;
    use daedalus::dsp::{
        CorruptionKind, SeriesPattern, TelemetryFaultEvent, TelemetryFaultTimeline,
    };
    use daedalus::experiments::Approach;

    let classes: Vec<(&str, TelemetryFaultTimeline)> = vec![
        (
            "metric-dropout",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricDropout {
                from: 250,
                to: 500,
            }]),
        ),
        (
            "metric-staleness",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricStaleness {
                from: 250,
                to: 500,
                delay: 120,
            }]),
        ),
        (
            "metric-corruption",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::MetricCorruption {
                from: 250,
                to: 500,
                pattern: SeriesPattern::WorkerSeries("worker_cpu"),
                kind: CorruptionKind::Nan,
                seed: 0x0BAD,
            }]),
        ),
        (
            "actuator-fault",
            TelemetryFaultTimeline::new(vec![TelemetryFaultEvent::ActuatorFault {
                from: 250,
                to: 500,
            }]),
        ),
    ];
    let approaches = [
        Approach::Daedalus(DaedalusConfig::default()),
        Approach::Daedalus(DaedalusConfig {
            hardened: false,
            ..DaedalusConfig::default()
        }),
    ];
    let reg = ScenarioRegistry::builtin(900, &[3]);
    for cell in ["flink-wordcount-sine", "flink-wordcount-bottleneck-shift"] {
        let scenario = reg.get(cell).expect("pinned cell registered");
        for (tag, tl) in &classes {
            for approach in &approaches {
                let run = |mode: EngineMode| {
                    let mut exp = scenario.to_experiment().unwrap();
                    exp.engine_mode = mode;
                    exp.telemetry = tl.clone();
                    exp.run_single_traced(approach, 3, scenario.workload(3), 60)
                };
                let (ra, ta) = run(EngineMode::PerTick);
                let (rb, tb) = run(EngineMode::EventDriven);
                let unit = format!("{cell}/{}/{tag}", approach.label());
                assert_eq!(ta.digest(), tb.digest(), "trace digest drift for {unit}");
                assert_eq!(ta.points, tb.points, "trace points drift for {unit}");
                assert_eq!(
                    ra.worker_seconds.to_bits(),
                    rb.worker_seconds.to_bits(),
                    "worker-seconds drift for {unit}"
                );
                assert_eq!(ra.latencies, rb.latencies, "latency ECDF drift for {unit}");
                assert_eq!(
                    ra.parallelism_series, rb.parallelism_series,
                    "parallelism-series drift for {unit}"
                );
                assert_eq!(ra.rescales, rb.rescales, "rescale-count drift for {unit}");
                assert_eq!(
                    ra.dropped_rescales, rb.dropped_rescales,
                    "dropped-rescale drift for {unit}"
                );
            }
        }
    }
}

/// Randomized-horizon fuzz for `advance_quiet`: correctness must never
/// depend on the caller's horizon choice. Split `[0, duration)` into
/// random sub-ranges — empty and single-tick ranges included — and
/// require bitwise agreement with one whole-horizon call and with the
/// per-tick reference, on a fused and a staged chaos cell (failure +
/// worker crash + gray-failure window inside the range).
#[test]
fn advance_quiet_agrees_for_any_random_horizon_split() {
    use daedalus::dsp::{
        EngineProfile, FaultEvent, FaultTimeline, SimConfig, Simulation, StageModel,
    };
    use daedalus::jobs::JobProfile;
    use daedalus::stats::Rng;
    use daedalus::workload::ConstantWorkload;

    const DURATION: u64 = 900;

    fn chaos_sim(staged: bool) -> Simulation {
        let cfg = SimConfig {
            partitions: if staged { 24 } else { 12 },
            initial_replicas: if staged { 2 } else { 4 },
            seed: 0xF0,
            failures: vec![300],
            faults: FaultTimeline::new(vec![
                FaultEvent::WorkerCrash { t: 520, k: 1 },
                FaultEvent::GrayFailure {
                    from: 700,
                    to: 760,
                    worker: 1,
                    severity: 0.5,
                },
            ]),
            stage_model: if staged {
                StageModel::Staged
            } else {
                StageModel::Fused
            },
            ..SimConfig::base(
                EngineProfile::flink(),
                JobProfile::wordcount(),
                Box::new(ConstantWorkload {
                    rate: 10_000.0,
                    duration: 10_000,
                }),
            )
        };
        Simulation::new(cfg)
    }

    fn assert_sims_bitwise_equal(a: &Simulation, b: &Simulation, unit: &str) {
        assert_eq!(a.latencies(), b.latencies(), "latency drift: {unit}");
        assert_eq!(a.tsdb(), b.tsdb(), "tsdb drift: {unit}");
        assert_eq!(
            a.total_consumed().to_bits(),
            b.total_consumed().to_bits(),
            "consumed drift: {unit}"
        );
        assert_eq!(
            a.total_backlog().to_bits(),
            b.total_backlog().to_bits(),
            "backlog drift: {unit}"
        );
        assert_eq!(
            a.worker_seconds().to_bits(),
            b.worker_seconds().to_bits(),
            "worker-seconds drift: {unit}"
        );
        assert_eq!(a.rescale_log, b.rescale_log, "rescale-log drift: {unit}");
    }

    for staged in [false, true] {
        let cell = if staged { "staged-chaos" } else { "fused-chaos" };
        // Per-tick reference and the whole-horizon event-driven call.
        let mut reference = chaos_sim(staged);
        for t in 0..DURATION {
            reference.step(t);
        }
        let mut whole = chaos_sim(staged);
        whole.advance_quiet(0, DURATION);
        assert_sims_bitwise_equal(&reference, &whole, &format!("{cell}/whole-horizon"));
        reference.check_invariants();
        whole.check_invariants();

        for case in 0..6u64 {
            let mut rng = Rng::new(0xF022 + case);
            let mut sim = chaos_sim(staged);
            let mut splits = Vec::new();
            let mut t = 0;
            while t < DURATION {
                // 0..=36-tick sub-ranges: ~3 % empty, plenty single-tick.
                let end = (t + rng.below(37)).min(DURATION);
                splits.push((t, end));
                sim.advance_quiet(t, end);
                if end == t {
                    // An empty range must be a no-op; take one real tick
                    // so the walk always terminates.
                    sim.advance_quiet(t, t + 1);
                    t += 1;
                } else {
                    t = end;
                }
            }
            assert_sims_bitwise_equal(
                &reference,
                &sim,
                &format!("{cell}/case-{case} splits {splits:?}"),
            );
            sim.check_invariants();
        }
    }
}

/// Truncated week/month-scale runs (real shapes, shortened horizon): the
/// modes still agree across a rescale-heavy diurnal trace, and the
/// flagship month cell produces a sane, fully-sampled trace under the
/// event-driven default.
#[test]
fn truncated_week_and_month_scale_runs_agree_and_stay_sane() {
    let reg = ScenarioRegistry::builtin(14_400, &[5]);
    for name in ["flink-wordcount-diurnal-week", "flink-wordcount-diurnal-month"] {
        let scenario = reg.get(name).unwrap();
        let exp = scenario.to_experiment().unwrap();
        // One reactive and one static approach keep the per-tick
        // reference runs CI-cheap while still exercising rescales.
        for approach in exp
            .approaches
            .iter()
            .filter(|a| matches!(a.label().as_str(), "daedalus" | "static-12"))
        {
            assert_modes_agree(scenario, approach, 5, 300);
            let (res, trace) = run_unit(scenario, approach, 5, EngineMode::EventDriven, 300);
            assert!(res.worker_seconds > 0.0, "{name}: no work accounted");
            assert!(res.final_backlog >= 0.0, "{name}: negative backlog");
            assert!(res.latencies.total_weight() > 0.0, "{name}: no latency mass");
            assert_eq!(trace.points.len(), (14_400 / 300) as usize, "{name}");
            assert!(trace.points.iter().all(|p| p.replicas >= 1), "{name}");
        }
    }
}
