//! CLI end-to-end tests: run the actual `daedalus` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_daedalus"))
}

#[test]
fn no_args_prints_usage_and_fails() {
    let out = bin().output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("usage: daedalus"));
    assert!(err.contains("figure"));
}

#[test]
fn unknown_figure_rejected() {
    let out = bin()
        .args(["figure", "fig99", "--backend", "native"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn fig3_quick_runs_and_writes_csv() {
    let dir = std::env::temp_dir().join("daedalus-cli-test");
    let out = bin()
        .args([
            "figure",
            "fig3",
            "--backend",
            "native",
            "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 3"));
    assert!(dir.join("fig3/per_worker.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_with_config_file() {
    let dir = std::env::temp_dir().join("daedalus-cli-run-test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("spec.json");
    std::fs::write(
        &cfg,
        r#"{
            "name": "cli-test",
            "duration": 1200,
            "seeds": [1],
            "approaches": ["daedalus", "static-6"]
        }"#,
    )
    .unwrap();
    let out = bin()
        .args(["run", "--config", cfg.to_str().unwrap(), "--backend", "native"])
        .current_dir(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("daedalus"));
    assert!(text.contains("static-6"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_with_bad_config_fails_cleanly() {
    let dir = std::env::temp_dir().join("daedalus-cli-bad-test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("bad.json");
    std::fs::write(&cfg, r#"{"approaches": ["wizardry"]}"#).unwrap();
    let out = bin()
        .args(["run", "--config", cfg.to_str().unwrap(), "--backend", "native"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("wizardry") || err.contains("unknown approach"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_list_prints_scenario_matrix() {
    let out = bin().args(["sweep", "--list"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("built-in scenarios"));
    // Paper cells and every new stress shape are addressable by name.
    for name in [
        "flink-wordcount-sine",
        "kstreams-ysb-ctr",
        "flink-wordcount-flash-crowd",
        "flink-wordcount-diurnal-drift",
        "flink-wordcount-outage-backfill",
        "flink-wordcount-sine-failstorm3",
        "flink-wordcount-bottleneck-shift",
        "kstreams-ysb-skew-amplify",
        "flink-wordcount-diurnal-week",
        "kstreams-ysb-diurnal-week",
    ] {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
}

#[test]
fn sweep_runs_selected_scenarios_and_prints_digests() {
    let dir = std::env::temp_dir().join("daedalus-cli-sweep-test");
    let out = bin()
        .args([
            "sweep",
            "--scenarios",
            "flink-wordcount-sine,flink-wordcount-flash-crowd",
            "--approaches",
            "daedalus,static-6",
            "--duration",
            "1200",
            "--threads",
            "2",
            "--stride",
            "60",
            "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("flink-wordcount-sine"));
    assert!(text.contains("flink-wordcount-flash-crowd"));
    assert!(text.contains("daedalus"));
    assert!(text.contains("trace digests:"));
    assert!(dir
        .join("traces/flink-wordcount-sine__daedalus__seed1.json")
        .exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_rejects_unknown_scenario() {
    let out = bin()
        .args(["sweep", "--scenarios", "no-such-scenario"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("no-such-scenario"), "{err}");
}

#[test]
fn bench_smoke_writes_schema_valid_json() {
    // Per-process-unique dir: concurrent `cargo test` runs must not race.
    let dir = std::env::temp_dir().join(format!("daedalus-cli-bench-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("BENCH_micro.json");
    let out = bin()
        .args([
            "bench",
            "--smoke",
            "--filter",
            "tsdb",
            "--out",
            out_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&out_path).unwrap();
    assert!(text.contains("\"schema\": \"daedalus-bench-micro/v1\""));
    assert!(text.contains("tsdb_avg_over_60s"));
    assert!(text.contains("\"smoke\": true"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn report_truncated_writes_byte_stable_artifacts() {
    let dir = std::env::temp_dir().join(format!("daedalus-cli-report-test-{}", std::process::id()));
    let run = || {
        bin()
            .args([
                "report",
                "--quick",
                "--sections",
                "fused-flink",
                "--scenarios",
                "flink-wordcount-sine",
                "--duration",
                "600",
                "--seeds",
                "1",
                "--out",
                dir.to_str().unwrap(),
            ])
            .output()
            .unwrap()
    };
    let out = run();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("paper-style evaluation report"), "{text}");
    assert!(text.contains("flink-wordcount-sine"));
    assert!(text.contains("vs static-12"));
    let report1 = std::fs::read_to_string(dir.join("REPORT.md")).unwrap();
    let csv = std::fs::read_to_string(dir.join("report.csv")).unwrap();
    assert!(csv.contains("reduction_vs_baseline_pct"));
    assert!(dir.join("report.json").exists());
    // A second invocation reproduces REPORT.md byte for byte.
    assert!(run().status.success());
    let report2 = std::fs::read_to_string(dir.join("REPORT.md")).unwrap();
    assert_eq!(report1, report2, "report bytes drifted across invocations");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn report_rejects_unknown_section() {
    let out = bin()
        .args(["report", "--quick", "--sections", "no-such-section"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("no-such-section"), "{err}");
}

#[test]
fn bench_check_strict_gates_on_regressions_only() {
    let dir = std::env::temp_dir().join(format!("daedalus-cli-strict-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("out.json");
    let tracked_slow = dir.join("tracked-slow.json");
    let tracked_fast = dir.join("tracked-fast.json");
    // A tracked trajectory claiming the bench takes ~nothing: any real
    // measurement is a >25 % regression.
    std::fs::write(
        &tracked_fast,
        r#"{"schema":"daedalus-bench-micro/v1","entries":[{"name":"tsdb_avg_over_60s","ns_per_iter":0.001,"iters":1,"min_ns":0.001,"max_ns":0.001}]}"#,
    )
    .unwrap();
    // And one claiming it takes ten minutes: never a regression.
    std::fs::write(
        &tracked_slow,
        r#"{"schema":"daedalus-bench-micro/v1","entries":[{"name":"tsdb_avg_over_60s","ns_per_iter":6e11,"iters":1,"min_ns":6e11,"max_ns":6e11}]}"#,
    )
    .unwrap();
    let base = |tracked: &std::path::Path, strict: bool| {
        let mut args = vec![
            "bench".to_string(),
            "--smoke".into(),
            "--filter".into(),
            "tsdb_avg_over_60s".into(),
            "--out".into(),
            out_path.to_str().unwrap().into(),
            "--check".into(),
            tracked.to_str().unwrap().into(),
        ];
        if strict {
            args.push("--strict".into());
        }
        bin().args(&args).output().unwrap()
    };
    // Report-only: the regression is printed but the run succeeds.
    let out = base(&tracked_fast, false);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("<< regression"));
    // --strict turns the same comparison into an exit-code gate.
    let out = base(&tracked_fast, true);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("regressed"));
    // No regression → --strict passes.
    let out = base(&tracked_slow, true);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // --strict without --check is a usage error.
    let out = bin()
        .args([
            "bench",
            "--smoke",
            "--filter",
            "tsdb_avg_over_60s",
            "--out",
            out_path.to_str().unwrap(),
            "--strict",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn selfcheck_native_backend() {
    let out = bin()
        .args(["selfcheck", "--backend", "native"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("selfcheck OK"));
    assert!(text.contains("forecast ok"));
}
