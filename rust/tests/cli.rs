//! CLI end-to-end tests: run the actual `daedalus` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_daedalus"))
}

#[test]
fn no_args_prints_usage_and_fails() {
    let out = bin().output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("usage: daedalus"));
    assert!(err.contains("figure"));
}

#[test]
fn unknown_figure_rejected() {
    let out = bin()
        .args(["figure", "fig99", "--backend", "native"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn fig3_quick_runs_and_writes_csv() {
    let dir = std::env::temp_dir().join("daedalus-cli-test");
    let out = bin()
        .args([
            "figure",
            "fig3",
            "--backend",
            "native",
            "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 3"));
    assert!(dir.join("fig3/per_worker.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_with_config_file() {
    let dir = std::env::temp_dir().join("daedalus-cli-run-test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("spec.json");
    std::fs::write(
        &cfg,
        r#"{
            "name": "cli-test",
            "duration": 1200,
            "seeds": [1],
            "approaches": ["daedalus", "static-6"]
        }"#,
    )
    .unwrap();
    let out = bin()
        .args(["run", "--config", cfg.to_str().unwrap(), "--backend", "native"])
        .current_dir(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("daedalus"));
    assert!(text.contains("static-6"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_with_bad_config_fails_cleanly() {
    let dir = std::env::temp_dir().join("daedalus-cli-bad-test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("bad.json");
    std::fs::write(&cfg, r#"{"approaches": ["wizardry"]}"#).unwrap();
    let out = bin()
        .args(["run", "--config", cfg.to_str().unwrap(), "--backend", "native"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("wizardry") || err.contains("unknown approach"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn selfcheck_native_backend() {
    let out = bin()
        .args(["selfcheck", "--backend", "native"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("selfcheck OK"));
    assert!(text.contains("forecast ok"));
}
