//! End-to-end runtime integration: load the AOT artifacts via PJRT, execute
//! them, and verify against (a) the golden vectors produced by the python
//! compile path and (b) the pure-Rust native mirror.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise).

use daedalus::runtime::{native, ArtifactRuntime, CapacityState, ComputeBackend};
use daedalus::util::json::Json;

fn artifacts_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn load_golden(dir: &str, name: &str) -> Json {
    let path = std::path::Path::new(dir).join("golden").join(name);
    Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap()
}

fn max_abs_rel_err(got: &[f32], want: &[f32]) -> f64 {
    assert_eq!(got.len(), want.len());
    got.iter()
        .zip(want)
        .map(|(g, w)| ((g - w).abs() as f64) / (w.abs() as f64 + 1.0))
        .fold(0.0, f64::max)
}

#[test]
fn capacity_artifact_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ArtifactRuntime::load(&dir).unwrap();
    let g = load_golden(&dir, "capacity.json");
    let mw = rt.meta.max_workers;

    let state =
        CapacityState::from_vec(g.get("state").unwrap().as_f32_vec().unwrap(), mw).unwrap();
    let xs = g.get("xs").unwrap().as_f32_vec().unwrap();
    let ys = g.get("ys").unwrap().as_f32_vec().unwrap();
    let mask = g.get("mask").unwrap().as_f32_vec().unwrap();
    let tgt = g.get("cpu_target").unwrap().as_f32_vec().unwrap();

    let out = rt.capacity_update(&state, &xs, &ys, &mask, &tgt).unwrap();

    let expect_state = g.get("expect_state").unwrap().as_f32_vec().unwrap();
    let expect_caps = g.get("expect_caps").unwrap().as_f32_vec().unwrap();
    let state_err = max_abs_rel_err(out.state.as_slice(), &expect_state);
    let caps_err = max_abs_rel_err(&out.capacities, &expect_caps);
    assert!(state_err < 1e-4, "state err {state_err}");
    assert!(caps_err < 1e-4, "caps err {caps_err}");
}

#[test]
fn forecast_artifact_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ArtifactRuntime::load(&dir).unwrap();
    let g = load_golden(&dir, "forecast.json");

    let history = g.get("history").unwrap().as_f32_vec().unwrap();
    let out = rt.forecast(&history).unwrap();

    let expect_fc = g.get("expect_forecast").unwrap().as_f32_vec().unwrap();
    let expect_coeffs = g.get("expect_coeffs").unwrap().as_f32_vec().unwrap();
    let fc_err = max_abs_rel_err(&out.forecast, &expect_fc);
    let coeff_err = max_abs_rel_err(&out.coeffs, &expect_coeffs);
    assert!(fc_err < 1e-3, "forecast err {fc_err}");
    assert!(coeff_err < 1e-3, "coeff err {coeff_err}");
    let expect_sigma = g.get("expect_resid_sigma").unwrap().as_f64().unwrap();
    assert!(((out.resid_sigma as f64) - expect_sigma).abs() / (expect_sigma + 1e-9) < 1e-3);
}

#[test]
fn artifact_and_native_backends_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ArtifactRuntime::load(&dir).unwrap();
    let meta = rt.meta.clone();

    // Capacity: synthetic warm-state update.
    let mw = meta.max_workers;
    let b = meta.obs_block;
    let mut xs = vec![0.0f32; mw * b];
    let mut ys = vec![0.0f32; mw * b];
    let mask = vec![1.0f32; mw * b];
    for w in 0..mw {
        for i in 0..b {
            let x = 0.3 + 0.6 * (i as f32 / b as f32);
            xs[w * b + i] = x;
            ys[w * b + i] = (45_000.0 + 1_000.0 * w as f32) * x + 13.0 * i as f32;
        }
    }
    let tgt = vec![0.9f32; mw];
    let state = CapacityState::zeros(mw);
    let art = rt.capacity_update(&state, &xs, &ys, &mask, &tgt).unwrap();
    let nat = native::capacity_update(&meta, &state, &xs, &ys, &mask, &tgt).unwrap();
    let err = max_abs_rel_err(&art.capacities, &nat.capacities);
    assert!(err < 1e-3, "capacity backend divergence {err}");

    // Forecast: noisy sine history.
    let hist: Vec<f32> = (0..meta.window)
        .map(|t| {
            let t = t as f64;
            (30e3 + 10e3 * (2.0 * std::f64::consts::PI * t / 1500.0).sin()
                + 100.0 * ((t * 2654435761.0).sin())) as f32
        })
        .collect();
    let art_fc = rt.forecast(&hist).unwrap();
    let nat_fc = native::forecast(&meta, &hist).unwrap();
    let err = max_abs_rel_err(&art_fc.forecast, &nat_fc.forecast);
    assert!(err < 5e-3, "forecast backend divergence {err}");
}

#[test]
fn compute_backend_enum_round_trip() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = ComputeBackend::artifact(&dir).unwrap();
    let meta = backend.meta().clone();
    let hist = vec![1_000.0f32; meta.window];
    let out = backend.forecast(&hist).unwrap();
    assert_eq!(out.forecast.len(), meta.horizon);
    // A constant series forecasts (approximately) itself.
    for v in &out.forecast {
        assert!((v - 1_000.0).abs() < 2.0, "{v}");
    }

    let native = ComputeBackend::native();
    let out2 = native.forecast(&hist).unwrap();
    let err = max_abs_rel_err(&out.forecast, &out2.forecast);
    assert!(err < 1e-3, "backend divergence {err}");
}
