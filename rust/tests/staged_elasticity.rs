//! Operator-level elasticity acceptance: the granularity dividend.
//!
//! On a `bottleneck-shift` run the pipeline's hot operator migrates
//! mid-run. A job-level controller must reconfigure the *whole job* to the
//! worst operator's requirement (Flink reactive mode applies one
//! parallelism to every operator), so every non-bottleneck stage is
//! over-provisioned the entire time. True per-operator DS2 sizes each
//! stage to its own minimal parallelism, so its total worker-seconds sit
//! strictly below the uniform deployment's.
//!
//! Why the comparison baseline is the uniform vector on the *staged*
//! engine: the retained fused pool (`StageModel::Fused`) models operator
//! *chaining*, where one worker runs the whole chain — under chaining
//! there is no per-operator allocation to waste, so `ceil(Σ demand)` is a
//! floor that per-stage `Σ ceil(demand_s)` can only approach (per-stage
//! integer ceilings cost up to one worker per stage). The economics the
//! ISSUE targets — and the one DS2/Demeter document — is per-operator vs
//! job-level *reconfiguration granularity* on a de-chained deployment,
//! which is exactly `ds2` vs `ds2-job` below. The fused run rides along as
//! the chained reference and must also beat the uniform deployment.

use daedalus::dsp::StageModel;
use daedalus::experiments::scenarios::{run_unit, ScenarioRegistry};
use daedalus::experiments::Scenario;

const DURATION: u64 = 3_600;
const SEED: u64 = 1;

fn bottleneck_shift() -> Scenario {
    let reg = ScenarioRegistry::builtin(DURATION, &[SEED]);
    reg.get("flink-wordcount-bottleneck-shift")
        .expect("staged scenario registered")
        .clone()
}

#[test]
fn per_operator_ds2_beats_job_level_ds2_on_bottleneck_shift() {
    let sc = bottleneck_shift();

    // True per-operator DS2: per-stage busy fractions → per-stage targets.
    let per_op = run_unit(&sc, "ds2", SEED, 60).unwrap();
    // Job-level DS2 on the same staged deployment: the worst operator's
    // requirement applied uniformly to every stage.
    let job_level = run_unit(&sc, "ds2-job", SEED, 60).unwrap();

    // The granularity dividend, strictly: fewer total worker-seconds.
    assert!(
        per_op.worker_seconds < job_level.worker_seconds,
        "per-operator DS2 used {} worker-seconds vs job-level {}",
        per_op.worker_seconds,
        job_level.worker_seconds
    );
    // And not by starving the pipeline: the run resolves — the backlog at
    // the end is bounded (a runaway under-provisioned run accumulates
    // hours of traffic; one in-flight catch-up is minutes).
    let peak = sc.job.profile().reference_peak;
    assert!(
        per_op.final_backlog < 90.0 * peak,
        "per-operator run did not resolve: final backlog {}",
        per_op.final_backlog
    );
    // The dividend is substantial, not a rounding artifact: the uniform
    // deployment pays ~(n_stages × bottleneck) while per-operator pays
    // ~Σ stage demands.
    assert!(
        per_op.worker_seconds < 0.85 * job_level.worker_seconds,
        "granularity dividend too small: {} vs {}",
        per_op.worker_seconds,
        job_level.worker_seconds
    );
}

#[test]
fn fused_chained_reference_also_beats_uniform_staged_deployment() {
    let sc = bottleneck_shift();
    let job_level = run_unit(&sc, "ds2-job", SEED, 60).unwrap();

    // The same scenario on the retained fused pool (operator chaining):
    // job-level DS2's classic formulation, with the drift expressed as a
    // time-varying whole-chain cost.
    let mut fused_sc = sc.clone();
    fused_sc.stage_model = StageModel::Fused;
    fused_sc.name = format!("{}-fused", sc.name);
    let fused = run_unit(&fused_sc, "ds2", SEED, 60).unwrap();

    assert!(
        fused.worker_seconds < job_level.worker_seconds,
        "chained reference {} should undercut the uniform staged deployment {}",
        fused.worker_seconds,
        job_level.worker_seconds
    );
    assert!(fused.worker_seconds > 0.0 && fused.final_backlog.is_finite());
}

#[test]
fn per_stage_plans_actually_differentiate_stages() {
    use daedalus::autoscaler::{Autoscaler, Ds2, Ds2Config};
    use daedalus::dsp::{SimConfig, Simulation};

    let sc = bottleneck_shift();
    let mut sim = Simulation::new(SimConfig {
        partitions: sc.partitions,
        initial_replicas: sc.initial_replicas,
        max_replicas: sc.max_replicas,
        seed: SEED,
        rate_noise: 0.02,
        stage_model: sc.stage_model,
        selectivity_drift: sc.selectivity_drift,
        zipf_override: sc.zipf_override,
        ..SimConfig::base(sc.engine.profile(), sc.job.profile(), sc.workload(SEED))
    });
    let mut ds2 = Ds2::new(Ds2Config::defaults(sc.max_replicas));
    let mut saw_non_uniform = false;
    let mut max_count_stage = 0usize;
    for t in 0..DURATION {
        sim.step(t);
        if let Some(plan) = ds2.decide_plan(&sim.view()) {
            sim.request_rescale_plan(&plan);
        }
        let v = sim.stage_parallelism();
        if v.iter().any(|&n| n != v[0]) {
            saw_non_uniform = true;
        }
        // Stage 2 (count-per-word) is WordCount's expensive keyed stage.
        max_count_stage = max_count_stage.max(v[2]);
    }
    assert!(
        saw_non_uniform,
        "per-operator DS2 never differentiated the stage vector"
    );
    assert!(
        max_count_stage >= 2,
        "the hot keyed stage was never scaled beyond one replica"
    );
    // The cheap sink stage must not have been dragged up to the hot
    // stage's parallelism at the end (that is the uniform failure mode).
    let v = sim.stage_parallelism().to_vec();
    assert!(
        v[3] <= v[2],
        "sink {} should not exceed the count stage {}",
        v[3],
        v[2]
    );
    sim.check_invariants();
}
